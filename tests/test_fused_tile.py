"""The parametric tile engine (`repro.kernels.fused_tile`): one kernel
parity matrix across transform families x engine scenarios x backends,
the three-stage structure through the same `TileKernelSpec`, block-shape
wisdom surviving tune.py's atomic rewrites, and the calibration cache.

Exactness oracle is always `lax.conv_general_dilated` to fp32 transform
tolerance.  The Pallas column runs in interpreter mode (CPU CI has no
TPU); the dedicated `pallas-interpret` CI job re-runs this file with
`REPRO_TILE_BACKEND=pallas_interpret` so the dispatch-level paths take
the kernel too.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analysis, pipeline, registry, tiling, transforms, tune
from repro.core.registry import ConvSpec
from repro.kernels.fused_tile import (
    BlockConfig,
    conv2d_fused_tile,
    engine_supported,
    resolve_backend,
    staged_matrix_fns,
)

BIG_HW = analysis.HardwareModel(
    name="big", peak_flops=1e12, dram_bw=1e11, fast_shared_bw=5e11,
    fast_shared_bytes=1 << 30, private_bytes=1 << 24,
)

FAMILIES = (
    transforms.WinogradTransform(m=3, k=3),  # T=5
    transforms.FFTTransform(t=8, k=3),  # complex re/im split planes
)

BACKENDS = ("xla", "pallas_interpret")

SCENARIOS = (
    "plain", "stride2", "grouped", "ragged", "bias_relu", "chunked",
)


def _lax_ref(x, w, pad=0, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def _rel(y, ref):
    return float(
        jnp.abs(y.astype(jnp.float32) - ref.astype(jnp.float32)).max()
        / (jnp.abs(ref.astype(jnp.float32)).max() + 1e-9)
    )


# ---------------------------------------------------- the parity matrix


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("scenario", SCENARIOS)
@pytest.mark.parametrize("tr", FAMILIES, ids=lambda t: t.family)
def test_kernel_parity_matrix(tr, scenario, backend):
    """Both transform families run the one parametric kernel on both
    engine backends and agree with the direct conv in every scenario."""
    rng = np.random.default_rng(11)
    groups = 2 if scenario == "grouped" else 1
    b, h, w, c_in, c_out = 2, 14, 14, 4, 4
    if scenario == "ragged":  # extents not a tile-grid multiple
        h, w = 13, 11
    x = jnp.asarray(rng.standard_normal((b, h, w, c_in)) * 0.1, jnp.float32)
    wk = jnp.asarray(
        rng.standard_normal((3, 3, c_in // groups, c_out)) * 0.1,
        jnp.float32,
    )
    assert engine_supported(tr, x.dtype)

    blocks = None
    if scenario == "chunked":  # bounded-working-set sweep (tpp > 0)
        blocks = BlockConfig(r=2, tasks_per_program=2)
    epilogue = None
    ref = _lax_ref(x, wk, pad=1, groups=groups)
    if scenario == "bias_relu":
        bvec = jnp.asarray(rng.standard_normal(c_out) * 0.1, jnp.float32)
        epilogue = registry.ElementwiseOps((("bias", bvec), ("relu",)))
        ref = jax.nn.relu(ref + bvec)

    y = conv2d_fused_tile(
        x, wk, tr, pad=1, blocks=blocks, groups=groups,
        epilogue=epilogue, backend=backend,
    )
    if scenario == "stride2":  # engine is stride-1 + decimation
        y = registry.decimate(y, 2)
        ref = _lax_ref(x, wk, pad=1, stride=2, groups=groups)
    assert y.shape == ref.shape, (tr.family, scenario, backend)
    assert _rel(y, ref) < 5e-5, (tr.family, scenario, backend)


@pytest.mark.parametrize("tr", FAMILIES, ids=lambda t: t.family)
def test_three_stage_through_same_spec(tr):
    """The materializing three-stage structure consumes the same
    `TileKernelSpec` as the fused kernel and stays exact -- all four
    transformed algorithms now share one parametric code path."""
    rng = np.random.default_rng(5)
    b, h, w, c_in, c_out = 2, 12, 12, 3, 5
    x = jnp.asarray(rng.standard_normal((b, h, w, c_in)) * 0.1, jnp.float32)
    wk = jnp.asarray(
        rng.standard_normal((3, 3, c_in, c_out)) * 0.1, jnp.float32
    )
    spec = tr.kernel_spec()
    assert spec is not None
    plan = tiling.TilePlan.build(h, w, tr.k, 1, tr.t)
    s1, s2, s3 = staged_matrix_fns(plan, spec)
    xp = tiling.pad_input(x, plan)
    wt = tr.kernel_transform(wk)  # family-native cached form
    y = s3(s2(s1(xp), wt), b).astype(x.dtype)
    ref = _lax_ref(x, wk, pad=1)
    assert y.shape == ref.shape
    assert _rel(y, ref) < 5e-5, tr.family


@pytest.mark.parametrize("backend", BACKENDS)
def test_two_link_fusion_group_epilogues(backend):
    """A two-link chain with bias+relu glue folded into each link's
    scatter phase equals the composed direct convs -- the engine form of
    a planned fusion group's interior."""
    rng = np.random.default_rng(7)
    tr = transforms.WinogradTransform(m=3, k=3)
    x = jnp.asarray(rng.standard_normal((2, 12, 12, 2)) * 0.1, jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((3, 3, 2, 3)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((3, 3, 3, 3)) * 0.1, jnp.float32)
    b1 = jnp.asarray(rng.standard_normal(3) * 0.1, jnp.float32)
    ep = registry.ElementwiseOps((("bias", b1), ("relu",)))
    mid = conv2d_fused_tile(x, w1, tr, pad=1, epilogue=ep, backend=backend)
    y = conv2d_fused_tile(mid, w2, tr, pad=1, backend=backend)
    ref_mid = jax.nn.relu(_lax_ref(x, w1, pad=1) + b1)
    ref = _lax_ref(ref_mid, w2, pad=1)
    assert _rel(y, ref) < 5e-5


def test_backend_resolution_order(monkeypatch):
    """Explicit argument > REPRO_TILE_BACKEND env > platform default."""
    monkeypatch.delenv("REPRO_TILE_BACKEND", raising=False)
    default = resolve_backend(None)
    assert default in ("xla", "pallas")
    monkeypatch.setenv("REPRO_TILE_BACKEND", "scan")
    assert resolve_backend(None) == "scan"
    assert resolve_backend("xla") == "xla"  # explicit wins over env
    monkeypatch.setenv("REPRO_TILE_BACKEND", "bogus")
    with pytest.raises(ValueError):
        resolve_backend(None)


def test_f64_gated_and_scan_fallback_exact(monkeypatch):
    """f64 is gated off the f32-basis kernel spec, and the dispatcher's
    scan fallback (the interpreting oracle) still serves exactly when
    the engine is forced off via the env override."""
    tr = transforms.WinogradTransform(m=3, k=3)
    assert not engine_supported(tr, jnp.dtype(jnp.float64))
    assert engine_supported(tr, jnp.dtype(jnp.float32))

    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((1, 10, 10, 2)) * 0.1, jnp.float32)
    wk = jnp.asarray(rng.standard_normal((3, 3, 2, 2)) * 0.1, jnp.float32)
    monkeypatch.setenv("REPRO_TILE_BACKEND", "scan")
    y = pipeline.fused_tile_conv(x, wk, tr, pad=1)
    assert _rel(y, _lax_ref(x, wk, pad=1)) < 5e-5


# --------------------------------------------------- block-shape wisdom


def _fresh(path):
    """Simulate a process restart: the mtime-validated in-memory wisdom
    cache is dropped, forcing a re-read from disk."""
    tune._WISDOM_CACHE.clear()
    return path


def test_block_wisdom_roundtrip_survives_atomic_rewrite(tmp_path):
    """Tuned block shapes written by `tuned_blocks` survive tune.py's
    atomic stamped rewrites of *other* entries and a process restart --
    the plan -> tune -> replan cycle's persistence contract."""
    path = tmp_path / "wisdom.json"
    tr = transforms.WinogradTransform(m=3, k=3)
    tuned = tune.tuned_blocks(
        12, 12, 2, 3, transform=tr, wisdom_path=path, backend="xla"
    )
    assert isinstance(tuned, BlockConfig)

    # an unrelated tuner rewrites the file (atomic replace, gen bump)
    tune.tuned_blocks(
        12, 12, 3, 2, transform=transforms.WinogradTransform(m=4, k=3),
        wisdom_path=path, backend="xla",
    )

    looked = tune.lookup_blocks(
        12, 12, 2, 3, transform=tr, wisdom_path=_fresh(path)
    )
    assert looked == tuned
    # the stamped entry merged, not clobbered: generation is monotonic
    # and the serialized blocks carry the tuned shape
    raw = json.loads(path.read_text())
    key = [k for k in raw if ":winograd:12x12x2->3:" in k]
    assert len(key) == 1
    entry = raw[key[0]]
    assert entry["blocks"] == tuned.to_wisdom()
    assert entry["gen"] >= 1 and entry["ts"] > 0


def test_tuned_blocks_preserves_prior_r(tmp_path):
    """A previously tuned R on the same key survives block tuning: the
    two wisdom dimensions merge into one stamped entry."""
    path = tmp_path / "wisdom.json"
    tr = transforms.WinogradTransform(m=3, k=3)
    tune.tuned_r(12, 12, 2, 3, transform=tr, wisdom_path=path)
    r_before = tune.lookup_r(12, 12, 2, 3, transform=tr, wisdom_path=path)
    assert r_before is not None
    tune.tuned_blocks(
        12, 12, 2, 3, transform=tr, wisdom_path=path, backend="xla"
    )
    assert tune.lookup_r(
        12, 12, 2, 3, transform=tr, wisdom_path=_fresh(path)
    ) == r_before
    assert tune.lookup_blocks(
        12, 12, 2, 3, transform=tr, wisdom_path=path
    ) is not None

    # and the reverse: an R pass on a blocks-only key merges too
    tr2 = transforms.WinogradTransform(m=4, k=3)
    tuned = tune.tuned_blocks(
        12, 12, 2, 3, transform=tr2, wisdom_path=path, backend="xla"
    )
    tune.tuned_r(12, 12, 2, 3, transform=tr2, wisdom_path=path)
    assert tune.lookup_blocks(
        12, 12, 2, 3, transform=tr2, wisdom_path=_fresh(path)
    ) == tuned


def test_plan_consumes_tuned_blocks_and_run_accepts_them(tmp_path):
    """Planning resolves tuned blocks into `params["blocks"]` (so the
    auto ranking prices the tuned engine) and execution reconstructs the
    BlockConfig -- and stays exact."""
    path = tmp_path / "wisdom.json"
    tr = transforms.WinogradTransform(m=3, k=3)
    blocks = BlockConfig(r=2, tasks_per_program=2)
    key = tune._key(tr, 12, 12, 2, 3)
    path.write_text(json.dumps(
        {key: {"blocks": blocks.to_wisdom(), "gen": 1, "ts": 1.0}}
    ))

    spec = ConvSpec(h=12, w=12, c_in=2, c_out=3, k=3, pad=1)
    ap = registry.plan_conv(
        spec, BIG_HW, algo="l3_fused", hints={"m": 3},
        wisdom_path=_fresh(path),
    )
    assert ap.params["blocks"] == blocks.to_wisdom()
    assert BlockConfig.from_wisdom(ap.params["blocks"]) == blocks

    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((2, 12, 12, 2)) * 0.1, jnp.float32)
    wk = jnp.asarray(rng.standard_normal((3, 3, 2, 3)) * 0.1, jnp.float32)
    alg = registry.get(ap.algo)
    y = alg.execute(x, wk, alg.prepare_weights(wk, ap), ap)
    assert _rel(y, _lax_ref(x, wk, pad=1)) < 5e-5


def test_untuned_plan_keeps_legacy_cost_charge(tmp_path):
    """Without tuned blocks the auto cost falls back to the static
    stride^2 charge; with them it prices the tuned engine -- the two
    sides of `fused_auto_cost`."""
    spec = ConvSpec(h=12, w=12, c_in=2, c_out=3, k=3, pad=1, stride=2)
    ap_untuned = registry.plan_conv(
        spec, BIG_HW, algo="l3_fused", hints={"m": 3},
        wisdom_path=tmp_path / "empty.json",
    )
    assert "blocks" not in ap_untuned.params
    ta = transforms.WinogradTransform(m=3, k=3).algebra
    tuned_cost = analysis.engine_cost_ta(
        BIG_HW, spec.c_in, spec.c_out, ta, 4, stride=spec.stride
    )
    assert tuned_cost is not None and tuned_cost > 0
    assert ap_untuned.cost != pytest.approx(tuned_cost)


# ------------------------------------------------------ calibration


def test_calibration_measures_once_and_caches(tmp_path):
    path = tmp_path / "wisdom.json"
    assert tune.lookup_calibration(path) is None
    first = tune.measure_calibration(path)
    assert first["peak_flops"] > 0 and first["dram_bw"] > 0
    again = tune.measure_calibration(_fresh(path))
    assert again["ts"] == first["ts"]  # served from the stamped cache
    assert tune.lookup_calibration(path)["peak_flops"] == first["peak_flops"]


def test_calibrated_hw_rescales_roofs(tmp_path):
    path = tmp_path / "wisdom.json"
    tune.measure_calibration(path)
    hw = analysis.calibrated_hw(analysis.SKYLAKE_X, wisdom_path=path)
    assert hw.name.endswith(":calibrated")
    assert hw.peak_flops > 0 and hw.dram_bw > 0
    # the fast-shared roof preserves the base machine's compute-to-fast
    # ratio, so residency heuristics keep their meaning after rescaling
    base = analysis.SKYLAKE_X
    assert hw.peak_flops / hw.fast_shared_bw == pytest.approx(
        base.cmr_fast, rel=1e-6
    )
