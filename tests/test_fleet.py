"""Elastic fleet serving under a simulated clock: sharded waves are
bit-exact vs the single-replica oracle, replicas add simulated
parallelism, the autoscaler grows/shrinks with hysteresis + admission
control, crashed replicas orphan waves into bounded-retry re-dispatch,
probes catch slow replicas and repair shared-cache corruption, and the
accounting invariant (admitted == served + lost) survives every drill."""

import math
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs.convnets import tiny_testnet
from repro.convserve import Engine, init_weights
from repro.convserve.fleet import (
    Autoscaler,
    AutoscalerConfig,
    ElasticPool,
    FixedServiceModel,
    FleetRuntime,
    LOSS_NO_HEALTHY_REPLICA,
    LOSS_REASONS,
    LOSS_RETRIES_EXHAUSTED,
    REPLICATE,
    SHARD,
    ShardedWaveExecutor,
    plan_weight_placement,
    shard_bounds,
)
from repro.convserve.runtime import (
    REJECT_SCALING,
    RuntimeConfig,
    SimClock,
    diurnal_rate,
    diurnal_trace,
    make_images,
    merge_traces,
    poisson_trace,
)
from repro.core import analysis
from repro.runtime.fault import (
    FAULT_CACHE_CORRUPT,
    FAULT_CRASH,
    FAULT_SLOW,
    FaultPlan,
    ReplicaFault,
)

BIG_HW = analysis.HardwareModel(
    name="big", peak_flops=1e12, dram_bw=1e11, fast_shared_bw=5e11,
    fast_shared_bytes=1 << 30, private_bytes=1 << 24,
)

SPEC = tiny_testnet(4)

SERVICE = FixedServiceModel(base_s=0.004, per_image_s=0.002)


def _fleet(n=2, *, shards=1, clock=None, cfg=None, autoscaler=None,
           adapt=None, fault_plan=None, **pool_kwargs):
    """Deterministic fleet: SimClock + fixed service model."""
    ws = init_weights(SPEC, seed=5)
    engine = Engine(hw=BIG_HW)
    clock = clock or SimClock()
    pool = ElasticPool.build(
        engine, SPEC, ws, n=n, clock=clock, input_hw=(16, 16),
        shards=shards, service_model=SERVICE, fault_plan=fault_plan,
        **pool_kwargs,
    )
    cfg = cfg or RuntimeConfig(
        buckets=(16,), max_batch=4, queue_depth=256,
        slo_s=0.25, service_est_s=0.012,
    )
    rt = FleetRuntime(pool, cfg, clock=clock,
                      autoscaler=autoscaler, adapt=adapt)
    return rt, clock


def _accounting(rt) -> dict:
    c = rt.stats()["counters"]
    served = c.get("images", 0)
    lost = c.get("lost_images", 0)
    assert served + lost == c.get("admitted", 0)
    return {"served": served, "lost": lost,
            "admitted": c.get("admitted", 0),
            "rejected": c.get("rejected", 0)}


class _AdaptStub:
    """Records pause/resume bracketing (the replanner's fleet surface)."""

    def __init__(self):
        self.events = []

    def pause(self, reason="x"):
        self.events.append(("pause", reason))

    def resume(self):
        self.events.append(("resume", None))


# ------------------------------------------------------------ traces


def test_diurnal_trace_is_seeded_and_shaped():
    a = diurnal_trace(50.0, 500, seed=3, period_s=10.0, sizes=(12, 16))
    b = diurnal_trace(50.0, 500, seed=3, period_s=10.0, sizes=(12, 16))
    assert a == b
    assert [r.t for r in a] == sorted(r.t for r in a)
    # the trough sits at t=0, the peak half a period in: 500 arrivals
    # at a 50 Hz mean span one full 10 s period, so the early-morning
    # window must be far quieter than the midday one
    trough = sum(1 for r in a if r.t % 10.0 < 1.5)
    peak = sum(1 for r in a if 4.0 <= r.t % 10.0 < 6.0)
    assert peak > 2 * trough > 0
    with pytest.raises(ValueError):
        diurnal_rate(50.0, depth=1.5)


def test_diurnal_rate_profile():
    rate = diurnal_rate(100.0, depth=0.5, period_s=10.0)
    assert rate(0.0) == pytest.approx(50.0)  # trough
    assert rate(5.0) == pytest.approx(150.0)  # peak
    assert rate(10.0) == pytest.approx(50.0)  # periodic


def test_merge_traces_dense_rids_preserve_payload():
    a = poisson_trace(100.0, 20, seed=1, sizes=(12,), priorities=(0,))
    b = poisson_trace(80.0, 15, seed=2, sizes=(16,), priorities=(2,))
    m = merge_traces(a, b)
    assert len(m) == 35
    assert [r.rid for r in m] == list(range(35))
    assert [r.t for r in m] == sorted(r.t for r in m)
    # payloads ride through: priority/size distributions are preserved
    assert sum(1 for r in m if r.priority == 2) == 15
    assert sum(1 for r in m if r.h == 12) == 20
    assert make_images(m, 4, seed=1).keys() == set(range(35))


# ----------------------------------------------------------- sharding


def test_shard_bounds_partition():
    assert shard_bounds(10, 4) == [(0, 3), (3, 6), (6, 8), (8, 10)]
    assert shard_bounds(2, 4) == [(0, 1), (1, 2)]  # never empty shards
    assert shard_bounds(8, 1) == [(0, 8)]
    assert shard_bounds(0, 4) == []
    # contiguous + exhaustive
    bounds = shard_bounds(17, 5)
    assert bounds[0][0] == 0 and bounds[-1][1] == 17
    assert all(bounds[i][1] == bounds[i + 1][0] for i in range(4))


def test_sharded_executor_bit_exact_on_ragged_wave():
    ws = init_weights(SPEC, seed=5)
    engine = Engine(hw=BIG_HW)
    net = engine.compile(SPEC, ws, input_hw=(16, 16))
    rng = np.random.default_rng(0)
    x = (rng.standard_normal((5, 16, 16, 4)) * 0.1).astype(np.float32)
    ext = np.array(
        [[16, 16], [12, 12], [16, 14], [8, 16], [0, 0]], np.int32
    )
    y1 = np.asarray(net(x, ext))
    sharded = ShardedWaveExecutor(
        engine.compile(SPEC, ws, plan=net.plan, input_hw=(16, 16)),
        shards=3,
    )
    assert np.array_equal(y1, np.asarray(sharded(x, ext)))
    # passthroughs keep the CompiledNet duck type intact
    assert sharded.spec is net.spec and sharded.cache is net.cache


def test_weight_placement_is_a_threshold_decision():
    ws = init_weights(SPEC, seed=5)
    engine = Engine(hw=BIG_HW)
    net = engine.compile(SPEC, ws, input_hw=(16, 16))
    net(np.zeros((1, 16, 16, 4), np.float32))  # make transforms resident
    tiny = plan_weight_placement(net, threshold_bytes=1)
    huge = plan_weight_placement(net, threshold_bytes=1 << 40)
    consuming = [
        layer for layer, d in tiny.items() if d["bytes"] > 0
    ]
    assert consuming, "tiny_testnet should have transformed layers"
    assert all(tiny[k]["placement"] == SHARD for k in consuming)
    assert all(d["placement"] == REPLICATE for d in huge.values())


# -------------------------------------------- exactness vs the oracle


def test_fleet_matches_single_replica_oracle_with_ragged_waves():
    trace = poisson_trace(
        45.0, 40, seed=7, sizes=(8, 12, 16), deadline_s=0.08,
    )
    images = make_images(trace, 4, seed=1)

    def serve(n, shards):
        rt, _ = _fleet(n, shards=shards, cfg=RuntimeConfig(
            buckets=(16,), max_batch=4, queue_depth=128,
            slo_s=0.1, service_est_s=0.01,
        ))
        rt.warmup([2, 4])
        out = rt.play(trace, images)
        return out, rt.stats()

    fleet_out, doc = serve(3, shards=2)
    oracle_out, _ = serve(1, shards=1)
    assert fleet_out.keys() == oracle_out.keys() == {a.rid for a in trace}
    for rid in oracle_out:
        assert np.array_equal(fleet_out[rid], oracle_out[rid]), rid
    # the deadline-flushed waves make the exactness claim cover ragged
    # partial batches, not just full ones
    assert doc["scheduler"]["partial_waves"] >= 1


# ------------------------------------------------- simulated elasticity


def test_replicas_add_simulated_parallelism():
    def makespan(n):
        trace = poisson_trace(5000.0, 240, seed=3, sizes=(16,))
        rt, clock = _fleet(n, cfg=RuntimeConfig(
            buckets=(16,), max_batch=4, queue_depth=512,
            slo_s=None, service_est_s=0.012,
        ))
        rt.warmup()
        rt.play(trace, make_images(trace, 4, seed=1))
        assert _accounting(rt)["served"] == 240
        return clock.now()

    m1, m4 = makespan(1), makespan(4)
    assert m4 < m1 / 2.5, (m1, m4)


def test_autoscaler_grows_under_pressure_and_gates_admission():
    adapt = _AdaptStub()
    auto = AutoscalerConfig(
        min_replicas=1, max_replicas=4,
        tick_interval_s=0.01, cooldown_s=0.05,
        queue_high=2.0, queue_low=0.1,
        slack_comfort_s=math.inf,  # never scale back down in this test
        admission_queue_per_replica=12.0,
    )
    rt, clock = _fleet(1, autoscaler=auto, adapt=adapt, startup_s=0.5)
    rt.warmup()
    img = np.zeros((16, 16, 4), np.float32)
    # flood one instant: queue pressure >> queue_high
    for i in range(40):
        rt.submit(img, rid=i, deadline_s=10.0)
    rt.run_until(0.2)  # several ticks: scale-up starts, replicas warm
    counts = rt.pool.counts()
    assert counts.get("starting", 0) >= 1, counts
    assert rt.autoscaler.scaling(clock.now())
    assert ("pause", "scale_event:up") in adapt.events
    # during the scale-up, admission above the READY replicas' cap is
    # shed with the reason-coded ``scaling`` rejection
    rejected = []
    for i in range(40, 80):
        r = rt.submit(img, rid=i, deadline_s=10.0)
        if r is not None:
            rejected.append(r)
    assert rejected and all(
        r.reason == REJECT_SCALING for r in rejected
    )
    # after startup the newcomers serve; the drain completes everything
    rt.run_until(1.0)
    assert rt.pool.ready_count() >= 2
    rt.drain()
    acct = _accounting(rt)
    assert acct["served"] == acct["admitted"] > 0
    assert acct["rejected"] == len(rejected)
    assert ("resume", None) in adapt.events  # settled after the reshape


def test_autoscaler_scales_down_and_drains_before_retire():
    auto = AutoscalerConfig(
        min_replicas=1, max_replicas=4,
        tick_interval_s=0.02, cooldown_s=0.05,
        queue_high=50.0, queue_low=0.5, slack_comfort_s=-math.inf,
    )
    rt, clock = _fleet(3, autoscaler=auto)
    rt.warmup()
    img = np.zeros((16, 16, 4), np.float32)
    for i in range(12):
        rt.submit(img, rid=i, deadline_s=5.0)
    rt.run_until(2.0)  # queue drains, then idle ticks shrink the fleet
    rt.drain()
    counts = rt.pool.counts()
    assert counts.get("retired", 0) >= 1, counts
    assert counts.get("ready", 0) >= auto.min_replicas
    acct = _accounting(rt)
    assert acct["served"] == 12 and acct["lost"] == 0


def test_pool_retire_waits_for_inflight_wave():
    rt, clock = _fleet(2)
    rt.warmup()
    img = np.zeros((16, 16, 4), np.float32)
    for i in range(8):  # two full waves: both replicas busy
        rt.submit(img, rid=i, deadline_s=5.0)
    rt.poll()
    assert rt.pool.ready_count() == 2 and not rt.pool.has_capacity()
    gone = rt.pool.retire(1)
    assert gone and rt.pool.counts().get("draining") == 1
    rt.drain()
    # the draining replica finished its wave before retiring: nothing
    # was lost and the wave landed
    assert rt.pool.counts().get("retired") == 1
    assert _accounting(rt)["served"] == 8


# ------------------------------------------------------------- faults


def test_crash_orphans_wave_into_retry_without_double_count():
    clock = SimClock()
    fp = FaultPlan(
        [ReplicaFault(t=0.016, kind=FAULT_CRASH, replica=0)], clock=clock
    )
    rt, _ = _fleet(2, clock=clock, fault_plan=fp)
    rt.warmup()
    trace = poisson_trace(400.0, 48, seed=3, sizes=(16,), deadline_s=1.0)
    rt.play(trace, make_images(trace, 4, seed=1))
    p = rt.stats()["pool"]
    assert p["failures"] == 1 and p["orphaned"] >= 1 and p["retries"] >= 1
    acct = _accounting(rt)
    assert acct["served"] == 48 and acct["lost"] == 0
    # a re-dispatched wave is still ONE wave everywhere it is counted
    doc = rt.stats()
    assert doc["counters"]["waves"] == doc["scheduler"]["waves"]
    assert doc["counters"]["images"] == 48  # no request served twice
    assert len(rt.results) == 48


def test_retries_exhausted_is_a_reason_coded_loss():
    clock = SimClock()
    fp = FaultPlan([
        ReplicaFault(t=0.010, kind=FAULT_CRASH, replica=0),
        ReplicaFault(t=0.012, kind=FAULT_CRASH, replica=1),
    ], clock=clock)
    rt, _ = _fleet(2, clock=clock, fault_plan=fp, max_retries=0)
    rt.warmup()
    img = np.zeros((16, 16, 4), np.float32)
    for i in range(16):
        rt.submit(img, rid=i, deadline_s=1.0)
    rt.drain()
    acct = _accounting(rt)  # asserts served + lost == admitted
    assert acct["lost"] >= 1
    assert set(rt.losses.values()) <= set(LOSS_REASONS)
    assert LOSS_RETRIES_EXHAUSTED in set(rt.losses.values())
    # queued waves dispatched after total fleet loss are losses too,
    # with their own reason
    p = rt.stats()["pool"]
    assert p["states"].get("failed") == 2
    if LOSS_NO_HEALTHY_REPLICA in p["losses"]:
        assert p["losses"][LOSS_NO_HEALTHY_REPLICA] >= 1
    # every admitted rid is in results or losses -- none vanished
    with rt._lock:
        assert set(rt.results) | set(rt.losses) == set(range(16))


def test_autoscaler_replaces_failed_replicas_ignoring_cooldown():
    clock = SimClock()
    fp = FaultPlan(
        [ReplicaFault(t=0.05, kind=FAULT_CRASH, replica=0)], clock=clock
    )
    auto = AutoscalerConfig(
        min_replicas=2, max_replicas=4,
        tick_interval_s=0.02, cooldown_s=1e9,  # cooldown would block "up"
        queue_high=1e9, queue_low=0.0,
    )
    rt, _ = _fleet(2, clock=clock, fault_plan=fp, autoscaler=auto,
                   startup_s=0.05)
    rt.warmup()
    rt.run_until(0.5)
    assert rt.stats()["autoscaler"]["replacements"] >= 1
    assert rt.pool.ready_count() >= 2


def test_cache_corruption_detected_and_repaired_by_probes():
    clock = SimClock()
    fp = FaultPlan(
        [ReplicaFault(t=0.5, kind=FAULT_CACHE_CORRUPT)], clock=clock
    )
    rt, _ = _fleet(2, clock=clock, fault_plan=fp, probe_interval_s=0.3)
    rt.warmup()
    rt.run_until(2.0)
    p = rt.stats()["pool"]
    assert p["probe_mismatches"] >= 2  # every replica saw the bad bytes
    assert p["cache_repairs"] == 1
    assert p["quarantines"] == 0  # shared fault, not a replica fault
    # post-repair serving is exact again
    trace = poisson_trace(200.0, 12, seed=3, sizes=(16,))
    out = rt.play(trace, make_images(trace, 4, seed=1))
    assert len(out) == 12


def test_slow_replica_is_quarantined_by_probes():
    clock = SimClock()
    fp = FaultPlan(
        [ReplicaFault(t=0.1, kind=FAULT_SLOW, replica=1, factor=8.0)],
        clock=clock,
    )
    rt, _ = _fleet(2, clock=clock, fault_plan=fp, probe_interval_s=0.2,
                   slow_quarantine_factor=2.5)
    rt.warmup()
    rt.run_until(1.0)
    p = rt.stats()["pool"]
    assert p["quarantines"] == 1
    assert p["states"].get("quarantined") == 1
    # the healthy replica keeps serving
    trace = poisson_trace(200.0, 12, seed=3, sizes=(16,))
    rt.play(trace, make_images(trace, 4, seed=1))
    assert _accounting(rt)["served"] == 12


def test_no_healthy_replica_losses_resolve_immediately():
    clock = SimClock()
    fp = FaultPlan(
        [ReplicaFault(t=0.001, kind=FAULT_CRASH, replica=0)], clock=clock
    )
    rt, _ = _fleet(1, clock=clock, fault_plan=fp)
    rt.warmup()
    clock.advance(0.01)
    rt.pool.advance(clock.now())
    img = np.zeros((16, 16, 4), np.float32)
    for i in range(4):
        rt.submit(img, rid=i, deadline_s=0.05)
    rt.drain()  # must terminate: doomed waves resolve to losses
    acct = _accounting(rt)
    assert acct["served"] == 0 and acct["lost"] == 4
    assert set(rt.losses.values()) == {LOSS_NO_HEALTHY_REPLICA}


# ---------------------------------------------------------- telemetry


def test_telemetry_schema_is_stable_across_scale_events():
    auto = AutoscalerConfig(
        min_replicas=1, max_replicas=3,
        tick_interval_s=0.01, cooldown_s=0.05,
        queue_high=2.0, queue_low=0.1,
    )
    clock = SimClock()
    fp = FaultPlan(
        [ReplicaFault(t=0.08, kind=FAULT_CRASH, replica=0)], clock=clock
    )
    rt, _ = _fleet(1, clock=clock, autoscaler=auto, fault_plan=fp,
                   startup_s=0.1)
    rt.warmup()
    img = np.zeros((16, 16, 4), np.float32)

    def schema(doc):
        top = set(doc)
        hist = {k: set(v) for k, v in doc["latency"].items()}
        return top, hist

    for i in range(30):
        rt.submit(img, rid=i, deadline_s=5.0)
    rt.run_until(0.05)
    top0, hist0 = schema(rt.stats())
    rt.run_until(0.2)  # crash + replacement + scale-up mid-trace
    top1, hist1 = schema(rt.stats())
    rt.drain()
    top2, hist2 = schema(rt.stats())
    assert top0 == top1 == top2
    for h in (hist0, hist1, hist2):
        for keys in h.values():
            assert keys == {"count", "mean_s", "p50_s", "p95_s",
                            "p99_s", "max_s"}
    # mid-scale histograms only ever grow (no counter reset mid-trace)
    doc = rt.stats()
    assert doc["counters"]["waves"] == doc["scheduler"]["waves"]
    acct = _accounting(rt)
    assert acct["served"] + acct["lost"] == 30


def test_fleet_stats_sections_are_json_clean():
    import json as _json

    auto = AutoscalerConfig(min_replicas=1, max_replicas=2,
                            tick_interval_s=0.01)
    rt, _ = _fleet(1, autoscaler=auto)
    rt.warmup()
    trace = poisson_trace(200.0, 8, seed=3, sizes=(16,))
    rt.play(trace, make_images(trace, 4, seed=1))
    doc = rt.stats()
    _json.dumps(doc)  # autoscaler/pool/faults sections all serialize
    assert {"pool", "scheduler", "cache", "autoscaler"} <= set(doc)
    assert doc["autoscaler"]["ticks"] >= 1
    assert doc["pool"]["states"] == {"ready": 1}


# ------------------------------------------------- real-mesh execution


_ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_sharded_wave_on_forced_8_device_mesh():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import numpy as np
            from repro.configs.convnets import tiny_testnet
            from repro.convserve import Engine, init_weights
            from repro.convserve.fleet import (
                ShardedWaveExecutor, apply_placement, plan_weight_placement,
            )
            from repro.core import analysis
            from repro.launch.mesh import make_host_mesh

            hw = analysis.HardwareModel(
                name="big", peak_flops=1e12, dram_bw=1e11,
                fast_shared_bw=5e11, fast_shared_bytes=1 << 30,
                private_bytes=1 << 24,
            )
            mesh = make_host_mesh(model=1)  # data axis = 8
            spec = tiny_testnet(4)
            ws = init_weights(spec, seed=5)
            engine = Engine(hw=hw)
            net = engine.compile(spec, ws, input_hw=(16, 16))
            rng = np.random.default_rng(0)
            x = (rng.standard_normal((8, 16, 16, 4)) * 0.1).astype(
                np.float32)
            ext = np.array([[16, 16]] * 6 + [[12, 12], [8, 16]], np.int32)
            y_ref = np.asarray(net(x, ext))
            sh = ShardedWaveExecutor(
                engine.compile(spec, ws, plan=net.plan, input_hw=(16, 16)),
                shards=8, mesh=mesh,
            )
            y = np.asarray(sh(x, ext))
            err = np.abs(y - y_ref).max()
            assert err < 1e-5, err
            # weight placement executes on the real mesh
            placement = plan_weight_placement(net, mesh=mesh,
                                              threshold_bytes=1)
            counts = apply_placement(net, mesh, placement)
            assert counts["sharded"] + counts["replicated"] >= 1, counts
            y2 = np.asarray(sh(x, ext))
            assert np.abs(y2 - y_ref).max() < 1e-5
            print("MESH_OK", dict(mesh.shape), counts)
        """)],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH_OK" in out.stdout


# ----------------------------------------------------- unit: autoscaler


def test_autoscaler_config_validates():
    with pytest.raises(ValueError):
        AutoscalerConfig(min_replicas=0)
    with pytest.raises(ValueError):
        AutoscalerConfig(queue_high=1.0, queue_low=2.0)
    with pytest.raises(ValueError):
        AutoscalerConfig(min_replicas=4, max_replicas=2)


def test_autoscaler_hysteresis_and_cooldown():
    rt, clock = _fleet(1, startup_s=0.01)
    cfg = AutoscalerConfig(
        min_replicas=1, max_replicas=3, tick_interval_s=0.1,
        cooldown_s=10.0, queue_high=4.0, queue_low=0.5,
    )
    depth = {"v": 0}
    auto = Autoscaler(rt.pool, cfg, queue_depth_fn=lambda: depth["v"])
    depth["v"] = 100
    clock.advance(0.15)
    assert auto.tick(clock.now()) == "up"
    rt.pool.advance(clock.now() + 0.02)
    # pressure persists but cooldown blocks the second grow
    clock.advance(0.15)
    assert auto.tick(clock.now()) is None
    # between ticks, nothing happens at all
    assert auto.tick(clock.now()) is None
    s = auto.stats()
    assert s["scale_ups"] == 1 and s["events"][0]["action"] == "up"
