"""Checkpoint save/restore: atomicity, keep-k, async, elastic restore."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((16,)), jnp.bfloat16),
        },
        "step": jnp.int32(7),
    }


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 7, t)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    restored, step = ckpt.restore(tmp_path, None, like)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_keep_k_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, t, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    steps = sorted(
        int(p.stem.split("_")[1]) for p in tmp_path.glob("step_*.done")
    )
    assert steps == [4, 5]


def test_async_checkpointer(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path), keep=3)
    t = _tree()
    c.save(1, t)
    c.save(2, t)  # joins the first
    c.wait()
    assert ckpt.latest_step(tmp_path) == 2


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(tmp_path, None, _tree())


def test_elastic_restore_new_sharding(tmp_path):
    """Restore with explicit (different) shardings -- the elastic path."""
    t = _tree()
    ckpt.save(tmp_path, 3, t)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), t)
    sh = jax.tree.map(
        lambda x: jax.sharding.SingleDeviceSharding(jax.devices()[0]), like
    )
    restored, step = ckpt.restore(tmp_path, 3, like, shardings=sh)
    assert step == 3
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(t["params"]["w"])
    )
