"""All convolution algorithms agree with the direct oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import conv2d, conv2d_direct, conv1d_depthwise_causal
from repro.kernels.fused_winograd.ref import conv2d_ref

ALGOS = ["three_stage", "l3_fused", "fft_fused", "l3_fused_pallas"]


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize(
    "shape", [(2, 12, 12, 8, 16, 1), (1, 20, 17, 4, 4, 0), (1, 9, 9, 3, 5, 1)]
)
def test_conv2d_matches_direct(algo, shape):
    b, h, w, c, cp, pad = shape
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.standard_normal((b, h, w, c)), jnp.float32)
    wk = jnp.asarray(rng.standard_normal((3, 3, c, cp)), jnp.float32)
    ref = conv2d_direct(x, wk, pad=pad)
    y = conv2d(x, wk, pad=pad, algo=algo, m=4, r_tiles=6)
    rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    assert rel < 5e-5, (algo, shape, rel)


def test_direct_matches_manual_ref():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((2, 10, 11, 5)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 5, 7)), jnp.float32)
    np.testing.assert_allclose(
        conv2d_direct(x, w, pad=1), conv2d_ref(x, w, pad=1), rtol=2e-4, atol=2e-4
    )


@given(
    b=st.integers(1, 2),
    h=st.integers(7, 24),
    w=st.integers(7, 24),
    c=st.integers(1, 8),
    cp=st.integers(1, 8),
    pad=st.integers(0, 2),
    m=st.integers(2, 6),
    r=st.integers(1, 9),
    algo=st.sampled_from(["three_stage", "l3_fused"]),
)
@settings(max_examples=25, deadline=None)
def test_conv2d_property(b, h, w, c, cp, pad, m, r, algo):
    rng = np.random.default_rng(b * h * w)
    x = jnp.asarray(rng.standard_normal((b, h, w, c)), jnp.float32)
    wk = jnp.asarray(rng.standard_normal((3, 3, c, cp)), jnp.float32)
    ref = conv2d_direct(x, wk, pad=pad)
    y = conv2d(x, wk, pad=pad, algo=algo, m=m, r_tiles=r)
    assert y.shape == ref.shape
    rel = float(jnp.abs(y - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 1e-4, (algo, (b, h, w, c, cp, pad, m, r), rel)


def test_conv1d_depthwise_causal():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 20, 6)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 6)), jnp.float32)
    y = conv1d_depthwise_causal(x, w)
    # manual: y[t] = sum_k x[t-K+1+k] w[k]
    xp = np.pad(np.asarray(x), ((0, 0), (3, 0), (0, 0)))
    ref = sum(xp[:, i : i + 20, :] * np.asarray(w)[i] for i in range(4))
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-5, atol=1e-5)
