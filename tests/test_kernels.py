"""Pallas kernels vs their pure-jnp oracles: shape/dtype sweeps (interpret)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels.conv1d_fused import conv1d_fused, conv1d_ref
from repro.kernels.decode_mlp import decode_mlp, decode_mlp_ref
from repro.kernels.fused_winograd import conv2d_fused_pallas, conv2d_ref


@pytest.mark.parametrize(
    "b,h,w,c,cp,k,pad,m,r",
    [
        (1, 16, 16, 8, 16, 3, 1, 5, 2),
        (2, 13, 21, 4, 8, 3, 0, 4, 3),
        (1, 30, 30, 16, 8, 3, 1, 6, 4),
        (1, 7, 7, 3, 3, 3, 1, 2, 2),
        (1, 24, 24, 8, 8, 5, 2, 4, 4),
    ],
)
def test_fused_winograd_shapes(b, h, w, c, cp, k, pad, m, r):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((b, h, w, c)), jnp.float32)
    wk = jnp.asarray(rng.standard_normal((k, k, c, cp)), jnp.float32)
    y = conv2d_fused_pallas(x, wk, pad=pad, m=m, r_tiles=r)
    ref = conv2d_ref(x, wk, pad=pad)
    assert y.shape == ref.shape
    rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    assert rel < 1e-4, rel


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_winograd_dtypes(dtype):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((1, 18, 18, 8)), dtype)
    wk = jnp.asarray(rng.standard_normal((3, 3, 8, 8)), dtype)
    y = conv2d_fused_pallas(x, wk, pad=1, m=4, r_tiles=4)
    ref = conv2d_ref(x, wk, pad=1)
    assert y.dtype == dtype
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    rel = float(
        jnp.abs(y.astype(jnp.float32) - ref.astype(jnp.float32)).max()
        / jnp.abs(ref.astype(jnp.float32)).max()
    )
    assert rel < tol, rel


@given(
    h=st.integers(7, 26),
    w=st.integers(7, 26),
    c=st.integers(1, 8),
    cp=st.integers(1, 8),
    m=st.integers(2, 5),
    r=st.integers(1, 6),
)
@settings(max_examples=20, deadline=None)
def test_fused_winograd_property(h, w, c, cp, m, r):
    rng = np.random.default_rng(h * w + c)
    x = jnp.asarray(rng.standard_normal((1, h, w, c)), jnp.float32)
    wk = jnp.asarray(rng.standard_normal((3, 3, c, cp)), jnp.float32)
    y = conv2d_fused_pallas(x, wk, pad=1, m=m, r_tiles=r)
    ref = conv2d_ref(x, wk, pad=1)
    assert y.shape == ref.shape
    rel = float(jnp.abs(y - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 1e-4


@given(
    b=st.integers(1, 3),
    l=st.integers(1, 70),
    d=st.integers(1, 16),
    k=st.integers(1, 5),
    lb=st.sampled_from([8, 16, 32]),
)
@settings(max_examples=20, deadline=None)
def test_conv1d_fused_property(b, l, d, k, lb):
    rng = np.random.default_rng(l * d)
    x = jnp.asarray(rng.standard_normal((b, l, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, d)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
    y = conv1d_fused(x, w, bias, lb=lb)
    ref = conv1d_ref(x, w, bias)
    assert y.shape == ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4, atol=1e-5)


@given(
    b=st.integers(1, 9),
    d=st.integers(4, 32),
    f=st.integers(4, 64),
    rb=st.sampled_from([2, 4, 8]),
    fb=st.sampled_from([8, 16, 64]),
)
@settings(max_examples=20, deadline=None)
def test_decode_mlp_property(b, d, f, rb, fb):
    rng = np.random.default_rng(b * d + f)
    x = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((d, f)) * 0.2, jnp.float32)
    w3 = jnp.asarray(rng.standard_normal((d, f)) * 0.2, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((f, d)) * 0.2, jnp.float32)
    y = decode_mlp(x, w1, w3, w2, rb=rb, fb=fb)
    ref = decode_mlp_ref(x, w1, w3, w2)
    assert y.shape == ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_conv1d_fused_smoke():
    """Example-based coverage so the kernel is exercised without hypothesis."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((2, 48, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal((8,)), jnp.float32)
    y = conv1d_fused(x, w, bias, lb=16)
    ref = conv1d_ref(x, w, bias)
    assert y.shape == ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_decode_mlp_smoke():
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((16, 32)) * 0.2, jnp.float32)
    w3 = jnp.asarray(rng.standard_normal((16, 32)) * 0.2, jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((32, 16)) * 0.2, jnp.float32)
    y = decode_mlp(x, w1, w3, w2, rb=4, fb=16)
    ref = decode_mlp_ref(x, w1, w3, w2)
    assert y.shape == ref.shape
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-4, atol=1e-5)
