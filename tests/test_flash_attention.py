"""Flash attention (custom VJP, tile-pair skipping) vs the chunked oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.models.attention import chunked_attention
from repro.models.flash_attention import _pairs, flash_attention


def _mk(rng, b, s, hq, hkv, hd):
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return q, k, v, pos


@pytest.mark.parametrize(
    "b,s,hq,hkv,hd,window,qb,kb,causal",
    [
        (2, 64, 4, 2, 16, 0, 16, 16, True),
        (1, 48, 4, 1, 8, 12, 16, 8, True),
        (2, 60, 2, 2, 8, 0, 16, 16, True),  # padding path
        (1, 64, 4, 4, 8, 0, 32, 16, False),  # encoder
        (1, 96, 8, 2, 16, 20, 16, 16, True),  # banded window
    ],
)
def test_fwd_and_grad_match_oracle(rng, b, s, hq, hkv, hd, window, qb, kb, causal):
    q, k, v, pos = _mk(rng, b, s, hq, hkv, hd)

    def f_flash(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(
            q, k, v, pos, pos, window=window, causal=causal,
            q_blk=qb, kv_blk=kb, p_dtype=jnp.float32)))

    def f_ref(q, k, v):
        return jnp.sum(jnp.sin(chunked_attention(
            q, k, v, pos, pos, window=window, causal=causal, chunk=16)))

    out = flash_attention(q, k, v, pos, pos, window=window, causal=causal,
                          q_blk=qb, kv_blk=kb, p_dtype=jnp.float32)
    ref = chunked_attention(q, k, v, pos, pos, window=window, causal=causal,
                            chunk=16)
    assert float(jnp.abs(out - ref).max()) < 2e-5
    gf = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gr):
        assert float(jnp.abs(a - b_).max()) < 5e-5


def test_bf16_p_matrix_tolerance(rng):
    q, k, v, pos = _mk(rng, 2, 64, 4, 2, 16)
    out = flash_attention(q, k, v, pos, pos, q_blk=16, kv_blk=16,
                          p_dtype=jnp.bfloat16)
    ref = chunked_attention(q, k, v, pos, pos, chunk=16)
    assert float(jnp.abs(out - ref).max()) < 3e-2  # bf16 epsilon regime


def test_pair_skipping_causal():
    # causal: lower-triangular tile pairs only
    p = _pairs(4, 4, 16, 16, causal=True, window=0, offset=0)
    assert len(p) == 10  # 4*5/2
    # sliding window w=16 with 16-wide tiles: diagonal + one back
    p = _pairs(4, 4, 16, 16, causal=True, window=16, offset=0)
    assert len(p) <= 8
    # non-causal global: all pairs
    p = _pairs(3, 3, 16, 16, causal=False, window=0, offset=0)
    assert len(p) == 9


@given(
    s=st.integers(16, 80),
    hq=st.sampled_from([2, 4, 8]),
    hkv=st.sampled_from([1, 2]),
    window=st.sampled_from([0, 8, 24]),
    qb=st.sampled_from([8, 16, 32]),
)
@settings(max_examples=12, deadline=None)
def test_flash_property(s, hq, hkv, window, qb):
    rng = np.random.default_rng(s * hq)
    q, k, v, pos = _mk(rng, 1, s, hq, hkv, 8)
    out = flash_attention(q, k, v, pos, pos, window=window, q_blk=qb,
                          kv_blk=qb, p_dtype=jnp.float32)
    ref = chunked_attention(q, k, v, pos, pos, window=window, chunk=8)
    assert out.shape == ref.shape
    assert float(jnp.abs(out - ref).max()) < 5e-5
