"""The unified algorithm registry: ConvSpec -> plan/prepare/execute.

Covers the api_redesign acceptance criteria: registry dispatch parity
with `lax.conv_general_dilated` across stride/groups/non-square/bf16,
ConvSpec + LayerPlan JSON round-trips with identical replans, wisdom-file
R resolution in ``algo="auto"``, and the no-silent-drop `wt` contract.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.convnets import resnet_downsample, resnext_grouped
from repro.convserve import (
    NetExecutor,
    NetPlan,
    init_weights,
    plan_layer,
    plan_net,
    run_direct,
)
from repro.core import analysis, conv2d, registry
from repro.core.registry import AlgoPlan, ConvSpec
from repro.convserve.plan import LayerPlan

BIG_HW = analysis.HardwareModel(
    name="big", peak_flops=1e12, dram_bw=1e11, fast_shared_bw=5e11,
    fast_shared_bytes=1 << 30, private_bytes=1 << 24,
)

TRANSFORMED = ("three_stage", "l3_fused", "fft_fused", "l3_fused_pallas")


def _lax_ref(x, w, pad, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def _rel(y, ref):
    return float(jnp.abs(y - ref).max() / (jnp.abs(ref).max() + 1e-9))


# ------------------------------------------------------------- registry


def test_all_algorithms_registered():
    names = registry.names()
    for expected in ("direct",) + TRANSFORMED:
        assert expected in names
    with pytest.raises(ValueError, match="unknown algo"):
        registry.get("warp_drive")


def test_supports_capability_filtering():
    # every 2-D algorithm covers the plain spec; the temporal conv1d
    # algorithm declines it (its domain is h==1 causal sequences)
    two_d = set(registry.names()) - {"conv1d_fused"}
    plain = ConvSpec(h=16, w=16, c_in=8, c_out=8, k=3, pad=1)
    assert set(registry.supporting(plain)) == two_d
    # grouped convs ride the shared engine's block-diagonal channel mix:
    # every 2-D algorithm covers them now
    grouped = dataclasses.replace(plain, groups=4)
    assert set(registry.supporting(grouped)) == two_d
    # fp8 is outside every transform family's compute domain except the
    # dtype-agnostic paths
    exotic = dataclasses.replace(plain, dtype="float8_e4m3fn")
    assert "fft_fused" not in registry.supporting(exotic)
    assert "direct" in registry.supporting(exotic)


def test_convspec_validation():
    with pytest.raises(ValueError):
        ConvSpec(h=16, w=16, c_in=6, c_out=8, k=3, groups=4)  # 6 % 4
    with pytest.raises(ValueError):
        ConvSpec(h=2, w=2, c_in=4, c_out=4, k=5, pad=0)  # kernel > input
    with pytest.raises(ValueError):
        ConvSpec(h=16, w=16, c_in=4, c_out=4, k=3, stride=0)


def test_auto_resolution_prefers_fused_then_falls_back():
    spec = ConvSpec(h=32, w=32, c_in=8, c_out=8, k=3, pad=1)
    ap = registry.plan_conv(spec, BIG_HW, hints={"m": 5})
    assert registry.get(ap.algo).tier == 0  # a fused path wins here
    tiny = ConvSpec(h=4, w=4, c_in=8, c_out=8, k=3, pad=0)
    ap = registry.plan_conv(tiny, BIG_HW, hints={"m": 5})
    assert ap.algo == "direct"  # nothing can tile a 4x4/pad-0 input


def test_explicit_unsupported_algo_raises():
    fp8 = ConvSpec(
        h=16, w=16, c_in=8, c_out=8, k=3, pad=1, dtype="float8_e4m3fn"
    )
    with pytest.raises(ValueError, match="does not support"):
        registry.plan_conv(fp8, BIG_HW, algo="fft_fused")


# ----------------------------------------------- dispatch parity vs lax


@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("algo", TRANSFORMED + ("auto",))
def test_conv2d_strided_matches_lax(algo, stride):
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((2, 17, 12, 6)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 6, 8)), jnp.float32)
    ref = _lax_ref(x, w, pad=1, stride=stride)
    y = conv2d(x, w, pad=1, stride=stride, algo=algo, m=4, r_tiles=6)
    assert y.shape == ref.shape
    assert _rel(y, ref) < 5e-5, (algo, stride)


@pytest.mark.parametrize("groups", [1, 4])
def test_conv2d_grouped_matches_lax(groups):
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.standard_normal((2, 14, 19, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 8 // groups, 16)), jnp.float32)
    ref = _lax_ref(x, w, pad=1, groups=groups)
    y = conv2d(x, w, pad=1, groups=groups, algo="auto")
    assert _rel(y, ref) < 5e-5


def test_conv2d_bf16():
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((1, 16, 16, 8)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((3, 3, 8, 8)), jnp.bfloat16)
    ref = _lax_ref(x, w, pad=1).astype(jnp.float32)
    for algo in ("auto", "l3_fused", "direct"):
        y = conv2d(x, w, pad=1, algo=algo, m=4, r_tiles=6)
        assert y.shape == ref.shape
        # bf16 has ~3 decimal digits; transformed paths accumulate more
        assert _rel(y.astype(jnp.float32), ref) < 0.1, algo


def test_conv2d_rejects_wt_for_nonconsuming_algo():
    """Satellite fix: a supplied `wt` must never be silently dropped."""
    rng = np.random.default_rng(14)
    x = jnp.asarray(rng.standard_normal((1, 12, 12, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 4)), jnp.float32)
    fake_wt = jnp.zeros((36, 4, 4), jnp.float32)
    for algo in ("direct", "l3_fused_pallas"):
        with pytest.raises(ValueError, match="pre-transformed"):
            conv2d(x, w, pad=1, algo=algo, wt=fake_wt)
    # and through the planned path too
    spec = ConvSpec(h=12, w=12, c_in=4, c_out=4, k=3, pad=1)
    lp = LayerPlan.from_algo_plan(
        0, registry.plan_conv(spec, BIG_HW, algo="direct")
    )
    with pytest.raises(ValueError, match="pre-transformed"):
        conv2d(x, w, plan=lp, wt=fake_wt)
    # consuming algorithms do accept a (correct) precomputed wt
    alg = registry.get("l3_fused")
    ap = registry.plan_conv(spec, BIG_HW, algo="l3_fused", hints={"m": 4})
    wt = alg.prepare_weights(w, ap)
    y = conv2d(x, w, plan=ap, wt=wt)
    assert _rel(y, _lax_ref(x, w, pad=1)) < 5e-5


# ------------------------------------------------------- serialization


def test_convspec_json_roundtrip():
    spec = ConvSpec(
        h=56, w=48, c_in=64, c_out=128, k=3, pad=1, stride=2, groups=2,
        dtype="bfloat16",
    )
    again = ConvSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert again == spec


def test_netplan_roundtrip_and_replan_identical():
    """A shipped plan file must reload equal AND replan equal: the plan
    is a pure function of (spec, hw, wisdom state)."""
    spec = resnet_downsample(c_in=3)
    plan = plan_net(spec, 32, 32, hw=analysis.SKYLAKE_X)
    again = NetPlan.from_json(plan.to_json())
    assert again == plan
    assert plan_net(spec, 32, 32, hw=analysis.SKYLAKE_X) == plan
    # params survive as algorithm-owned dicts
    for p in again.layers:
        assert isinstance(p.params, dict)
        assert p.spec.stride in (1, 2)


# ------------------------------------------------------- wisdom in auto


def test_auto_resolves_r_through_wisdom_file(tmp_path, monkeypatch):
    """Satellite fix: algo="auto" must use a tuned R when the wisdom file
    has one for this geometry (the seed dispatcher always ran the default
    R).  No measuring may happen at dispatch time."""
    from repro.core import tune

    spec = ConvSpec(h=32, w=32, c_in=8, c_out=8, k=3, pad=1)
    path = tmp_path / "wisdom.json"
    # without wisdom: the analytic prediction
    ap = registry.plan_conv(spec, BIG_HW, hints={"m": 5}, wisdom_path=path)
    assert ap.algo in ("l3_fused", "fft_fused")
    assert not ap.tuned
    # write a tuned entry for the winning wino geometry and replan
    # (wisdom keys carry the transform family + tile size, so this entry
    # can never collide with an FFT tune of the same layer)
    from repro.core import transforms

    key = tune._key(transforms.WinogradTransform(m=5, k=3), 32, 32, 8, 8)
    path.write_text(json.dumps({key: 16}))
    monkeypatch.setattr(
        tune, "measure_r",
        lambda *a, **k: (_ for _ in ()).throw(AssertionError("measured!")),
    )
    ap2 = registry.plan_conv(
        spec, BIG_HW, algo="l3_fused", hints={"m": 5}, wisdom_path=path
    )
    assert ap2.params["r_tiles"] == 16
    assert ap2.tuned
    # the planner surfaces the same R without tune_r=True
    lp = plan_layer(BIG_HW, 0, spec, consider_fft=False, wisdom_path=path)
    assert lp.algo == "l3_fused"
    assert lp.r_tiles == 16 and lp.tuned


# --------------------------------------------- new-scenario end-to-end


def test_stride2_net_plans_transformed_and_matches_direct():
    """Acceptance: the stride-2 downsampling net must plan at least one
    transformed-path layer and serve to fp32 tolerance vs the oracle."""
    spec = resnet_downsample(c_in=3)
    plan = plan_net(spec, 32, 32, hw=analysis.SKYLAKE_X)
    tiers = [registry.get(a).tier for a in plan.algos()]
    assert 0 in tiers or 1 in tiers  # transformed path planned
    assert any(p.spec.stride == 2 and registry.get(p.algo).tier < 2
               for p in plan.layers)
    ws = init_weights(spec, seed=2)
    ex = NetExecutor(spec, ws, plan)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((2, 32, 32, 3)) * 0.1, jnp.float32)
    y = ex(x)
    ref = run_direct(spec, ws, x)
    assert y.shape == ref.shape
    assert _rel(y, ref) < 1e-3, plan.algos()


def test_grouped_net_plans_transformed_and_matches():
    """Grouped layers reach the transformed paths through the engine's
    block-diagonal channel mix (they used to fall back to direct)."""
    spec = resnext_grouped(c_in=4, groups=4)
    plan = plan_net(spec, 16, 16, hw=BIG_HW)
    grouped_layers = [p for p in plan.layers if p.spec.groups > 1]
    assert grouped_layers and all(
        registry.get(p.algo).tier < 2 for p in grouped_layers
    )
    ws = init_weights(spec, seed=4)
    ex = NetExecutor(spec, ws, plan)
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((1, 16, 16, 4)) * 0.1, jnp.float32)
    assert _rel(ex(x), run_direct(spec, ws, x)) < 1e-3


def test_layerplan_properties_view_spec_and_params():
    spec = ConvSpec(h=16, w=16, c_in=8, c_out=8, k=3, pad=1, stride=2)
    lp = LayerPlan.from_algo_plan(
        3, AlgoPlan("l3_fused", spec, {"m": 4, "r_tiles": 6})
    )
    assert (lp.h, lp.w, lp.c_in, lp.c_out, lp.k) == (16, 16, 8, 8, 3)
    assert (lp.pad, lp.stride, lp.groups) == (1, 2, 1)
    assert (lp.m, lp.r_tiles, lp.t_fft, lp.t) == (4, 6, None, 6)
    assert LayerPlan.from_dict(lp.to_dict()) == lp
