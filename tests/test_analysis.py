"""The paper's S5 analytical model, as assertions."""

from repro.core import analysis as an


def test_ai_l3_is_r_over_2():
    assert an.ai_fast_level(24) == 12.0
    # paper: SkylakeX CMR_L3 ~ 10 => R >= 20; i7 CMR_L3 ~ 4 => R >= 8
    assert an.min_r(an.SKYLAKE_X) == 20
    assert an.min_r(an.MOBILE_I7) == 8


def test_dram_cmr_matches_paper():
    # paper: "Which was 35 for the SkylakeX and 13 for the i7"
    assert round(an.SKYLAKE_X.cmr_dram) == 35
    assert round(an.MOBILE_I7.cmr_dram) == 13


def test_ai_dram_channel_bound():
    # AI_dram ~ C C' / (2 (C + C')) >= min(C, C')/4 (paper S5.1)
    for c, cp in [(32, 32), (64, 128), (256, 64)]:
        ai = an.ai_dram(c, cp, t=7, t_out=5)
        assert ai >= min(c, cp) / 4 * 0.5  # T'<T shrinks output bytes a bit

def test_kernel_matrix_footprint():
    # paper S4.1.1: FFT T=16, 32ch -> ~1MB; Winograd T=8 128ch -> 4MB
    assert an.kernel_matrix_bytes(32, 32, 16) == 1 * 1024 ** 2
    assert an.kernel_matrix_bytes(128, 128, 8) == 4 * 1024 ** 2


def test_choose_algo_crossover():
    """Fused wins at low channel counts, 3-stage at high (paper Fig 2)."""
    hw = an.SKYLAKE_X
    assert an.choose_algo(hw, 64, 64, 8) == "l3_fused"
    assert an.choose_algo(hw, 128, 128, 8) == "l3_fused"
    assert an.choose_algo(hw, 1024, 1024, 8) == "three_stage"


def test_tpu_adaptation_cmr():
    # HBM CMR on v5e ~ 240 -- 7x the SkylakeX DRAM CMR: fusion matters more
    assert 200 < an.TPU_V5E.cmr_dram < 280
