"""Pallas flash-attention kernel vs pure-jnp oracle (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels.flash_attention import attention_ref, flash_attention_pallas


@pytest.mark.parametrize(
    "b,hq,hkv,s,hd,causal,window,qb,kb",
    [
        (2, 4, 2, 64, 16, True, 0, 16, 16),
        (1, 8, 1, 48, 8, True, 12, 16, 8),
        (1, 2, 2, 60, 8, True, 0, 16, 16),  # padding path
        (2, 4, 4, 64, 8, False, 0, 32, 16),
        (1, 4, 2, 96, 16, True, 24, 16, 16),  # banded window
    ],
)
def test_matches_oracle(rng, b, hq, hkv, s, hd, causal, window, qb, kb):
    q = jnp.asarray(rng.standard_normal((b, hq, s, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, hd)), jnp.float32)
    y = flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_blk=qb, kv_blk=kb
    )
    ref = attention_ref(q, k, v, causal=causal, window=window)
    assert y.shape == ref.shape
    assert float(jnp.abs(y - ref).max()) < 5e-6


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(rng, dtype):
    q = jnp.asarray(rng.standard_normal((1, 4, 32, 16)), dtype)
    k = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), dtype)
    v = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), dtype)
    y = flash_attention_pallas(q, k, v, q_blk=16, kv_blk=16)
    ref = attention_ref(q, k, v)
    assert y.dtype == dtype
    tol = 3e-2 if dtype == jnp.bfloat16 else 5e-6
    err = float(
        jnp.abs(y.astype(jnp.float32) - ref.astype(jnp.float32)).max()
    )
    assert err < tol


@given(
    s=st.integers(16, 96),
    hq=st.sampled_from([2, 4]),
    hkv=st.sampled_from([1, 2]),
    window=st.sampled_from([0, 8, 24]),
    blk=st.sampled_from([8, 16, 32]),
)
@settings(max_examples=10, deadline=None)
def test_property_sweep(s, hq, hkv, window, blk):
    rng = np.random.default_rng(s + hq)
    q = jnp.asarray(rng.standard_normal((1, hq, s, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, hkv, s, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, hkv, s, 8)), jnp.float32)
    y = flash_attention_pallas(q, k, v, window=window, q_blk=blk, kv_blk=blk)
    ref = attention_ref(q, k, v, window=window)
    assert float(jnp.abs(y - ref).max()) < 5e-6
