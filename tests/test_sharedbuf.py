"""Shared-buffer planner: the paper's S4.2 aliasing invariant + savings."""

from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.sharedbuf import SharedBufferPlan, max_r_for_budget


@given(
    r=st.integers(1, 64),
    c_in=st.integers(1, 512),
    c_out=st.integers(1, 512),
    t=st.integers(2, 16),
)
@settings(max_examples=100, deadline=None)
def test_aliasing_invariant(r, c_in, c_out, t):
    plan = SharedBufferPlan(r=r, c_in=c_in, c_out=c_out, t2=t * t)
    plan.validate()  # result s never touches lhs >= s
    # buffer is never larger than naive storage, and close to the paper bound
    assert plan.bytes <= plan.naive_bytes + 4 * plan.r * plan.width
    assert plan.bytes >= plan.paper_bound_bytes - 4 * plan.r * plan.width


def test_savings_match_paper_figure1a():
    """Fig 1(a): C == C' -> ~(T^2-1)/(2 T^2) saving; for 4 matmuls of equal
    size the paper reports 37.5% (40 slots vs 64)."""
    plan = SharedBufferPlan(r=1, c_in=8, c_out=8, t2=4)
    # rows: (4+1)*1 = 5 of width 8 = 40 slots vs naive 4*(8+8) = 64
    assert plan.rows * plan.width == 40
    assert plan.naive_bytes == 64 * 4
    assert abs(plan.savings - 0.375) < 1e-9


def test_max_r_budget_monotonic():
    r1 = max_r_for_budget(512 * 1024, 64, 64, 8)
    r2 = max_r_for_budget(1024 * 1024, 64, 64, 8)
    assert r2 >= r1 >= 1
    # shared buffer admits ~2x larger R than separate buffers (paper S4.2)
    r_shared = max_r_for_budget(512 * 1024, 64, 64, 8, shared=True)
    r_naive = max_r_for_budget(512 * 1024, 64, 64, 8, shared=False)
    assert r_shared >= int(1.8 * r_naive)
