"""Optional-hypothesis shim.

`hypothesis` is a dev-only dependency; without it the property tests must
skip while every example-based test in the same module still runs.  Test
modules import `given, settings, st` from here instead of from hypothesis:
when the real package is present these are the real objects, otherwise
`given(...)` swaps the test for a skip-marked stub and `st`/`settings`
degrade to inert placeholders.
"""

__all__ = ["given", "settings", "st", "HAS_HYPOTHESIS"]

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis absent
    import pytest

    HAS_HYPOTHESIS = False

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def settings(*_a, **_k):
        return lambda fn: fn
