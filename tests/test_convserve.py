"""convserve engine: planner decisions, kernel cache, plan round-trip,
numerical agreement with the direct oracle, and the serving front-end."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.convnets import tiny_testnet, vgg_style
from repro.convserve import (
    ConvServeConfig,
    ConvSpec,
    ConvServer,
    ImageRequest,
    KernelCache,
    NetExecutor,
    NetPlan,
    NetSpec,
    conv,
    init_weights,
    plan_layer,
    plan_net,
    run_direct,
)
from repro.core import analysis

# Synthetic machines that force each decision regardless of host backend:
# BIG's shared level swallows any kernel matrices (fused paths feasible);
# TINY's 2 KB shared level rejects them all (three_stage everywhere).
BIG_HW = analysis.HardwareModel(
    name="big", peak_flops=1e12, dram_bw=1e11, fast_shared_bw=5e11,
    fast_shared_bytes=1 << 30, private_bytes=1 << 24,
)
TINY_HW = analysis.HardwareModel(
    name="tiny", peak_flops=1e12, dram_bw=1e11, fast_shared_bw=5e11,
    fast_shared_bytes=2048, private_bytes=4096,
)


# ---------------------------------------------------------------- planner


def test_planner_three_stage_when_kernels_overflow_shared_level():
    spec = tiny_testnet(4)
    plan = plan_net(spec, 16, 16, hw=TINY_HW)
    assert plan.algos() == ("three_stage",) * 4
    # sanity: the same net on a huge shared level plans fused
    plan_big = plan_net(spec, 16, 16, hw=BIG_HW, consider_fft=False)
    assert plan_big.algos() == ("l3_fused",) * 4


def test_planner_fused_r_within_bounds():
    plan = plan_net(tiny_testnet(4), 16, 16, hw=BIG_HW, consider_fft=False)
    for p in plan.layers:
        assert p.algo == "l3_fused"
        assert 1 <= p.r_tiles <= analysis.max_r(BIG_HW, p.c_in, p.c_out, p.t)
        assert 0.0 < p.predicted_util <= 1.0


def test_planner_direct_for_degenerate_spatial():
    spec = NetSpec("dot", (conv(4, 8, k=3, pad=0),))
    plan = plan_net(spec, 4, 4, hw=BIG_HW)  # 4x4 input < 7x7 tile
    assert plan.algos() == ("direct",)


def test_planner_mixed_algorithms_across_channel_widths():
    """The paper's crossover: few-channel layers fuse, many-channel layers
    overflow the shared level and fall back to the vendor structure."""
    spec = vgg_style("mix", 3, widths=(64, 256), convs_per_stage=2)
    plan = plan_net(spec, 32, 32, hw=analysis.SKYLAKE_X)
    assert len(set(plan.algos())) >= 2
    assert plan.layer_plan(spec.conv_layers()[0][0]).algo == "l3_fused"
    assert plan.layer_plan(spec.conv_layers()[-1][0]).algo == "three_stage"


def test_choose_algo_considers_fft():
    # K=5 shrinks the Winograd output tile (T'=4 at T=8) while FFT at T=16
    # keeps T'=12: FFT wins on the i7 model despite alpha=2 FLOPs.
    assert (
        analysis.choose_algo(analysis.MOBILE_I7, 16, 16, 8, k=5) == "fft_fused"
    )
    # existing Winograd-vs-3-stage crossover is unchanged by the extension
    assert analysis.choose_algo(analysis.SKYLAKE_X, 64, 64, 8) == "l3_fused"
    assert (
        analysis.choose_algo(analysis.SKYLAKE_X, 1024, 1024, 8)
        == "three_stage"
    )


# ------------------------------------------------------------ plan format


def test_netplan_json_roundtrip(tmp_path):
    plan = plan_net(tiny_testnet(4), 16, 16, hw=BIG_HW)
    again = NetPlan.from_json(plan.to_json())
    assert again == plan
    path = tmp_path / "plans" / "tiny.json"
    plan.save(path)
    assert NetPlan.load(path) == plan
    # the on-disk form is plain JSON with per-layer records
    raw = json.loads(path.read_text())
    assert raw["net"] == "tiny-testnet"
    assert len(raw["layers"]) == 4


def test_netplan_rejects_unknown_algo():
    plan = plan_net(tiny_testnet(4), 16, 16, hw=BIG_HW)
    d = json.loads(plan.to_json())
    d["layers"][0]["algo"] = "warp_drive"
    with pytest.raises(ValueError):
        NetPlan.from_json(json.dumps(d))


# ----------------------------------------------------------- kernel cache


def test_kernel_cache_hit_miss_accounting():
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=1)
    plan = plan_net(spec, 16, 16, hw=BIG_HW, consider_fft=False)
    cache = KernelCache()
    for i, _ in spec.conv_layers():
        cache.get(plan.net, plan.layer_plan(i), ws[i])
    assert cache.stats()["misses"] == 4 and cache.stats()["hits"] == 0
    for i, _ in spec.conv_layers():
        cache.get(plan.net, plan.layer_plan(i), ws[i])
    assert cache.stats()["misses"] == 4 and cache.stats()["hits"] == 4
    assert cache.stats()["entries"] == 4
    cache.invalidate(plan.net)
    assert cache.stats()["entries"] == 0


def test_shared_cache_isolates_executors_with_different_weights():
    """Two executors serving the same net from one cache but with
    different parameters must not serve each other's transforms."""
    spec = tiny_testnet(4)
    plan = plan_net(spec, 16, 16, hw=BIG_HW)
    cache = KernelCache()
    ws1 = init_weights(spec, seed=1)
    ws2 = init_weights(spec, seed=2)
    ex1 = NetExecutor(spec, ws1, plan, cache=cache)
    ex2 = NetExecutor(spec, ws2, plan, cache=cache)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 16, 16, 4)) * 0.1, jnp.float32)
    ex1(x)
    y2 = ex2(x)
    ref2 = run_direct(spec, ws2, x)
    rel = float(jnp.abs(y2 - ref2).max() / jnp.abs(ref2).max())
    assert rel < 1e-3, rel  # would be ~1.4 if ex2 hit ex1's entries
    # distinct weights -> distinct entries; identical weights -> shared
    assert cache.stats()["entries"] == 8
    ex3 = NetExecutor(spec, init_weights(spec, seed=1), plan, cache=cache)
    ex3(x)
    assert cache.stats()["entries"] == 8  # ex3 reused ex1's transforms


def test_planner_skips_fft_below_tile_size():
    """FFT's T=16 tile must not be planned for layers whose padded input
    cannot fill it (the cost model assumes full output tiles)."""
    small = ConvSpec(h=8, w=8, c_in=16, c_out=16, k=3, pad=1)
    p = plan_layer(BIG_HW, 0, small)  # 10x10 padded < 16
    assert p.algo != "fft_fused"
    big = ConvSpec(h=16, w=16, c_in=16, c_out=16, k=3, pad=1)
    p = plan_layer(BIG_HW, 0, big)  # 18x18 covers a tile
    assert p.algo == "fft_fused"


def test_kernel_cache_distinguishes_layers_with_same_geometry():
    """Layers 2 and 4 of the testnet share (c_in, c_out, k) but hold
    different weights: the cache must keep separate entries."""
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=1)
    plan = plan_net(spec, 16, 16, hw=BIG_HW, consider_fft=False)
    cache = KernelCache()
    convs = spec.conv_layers()
    same_geom = [
        (i, l) for i, l in convs if (l.c_in, l.c_out) == (8, 8)
    ] or convs[:2]
    (i1, _), (i2, _) = same_geom[0], convs[-1]
    wt1 = cache.get(plan.net, plan.layer_plan(i1), ws[i1])
    wt2 = cache.get(plan.net, plan.layer_plan(i2), ws[i2])
    assert cache.stats()["misses"] == 2
    assert wt1 is not wt2


# -------------------------------------------------------------- executor


@pytest.mark.parametrize(
    "hw,kwargs",
    [
        (BIG_HW, {"consider_fft": False}),  # all l3_fused
        (BIG_HW, {}),  # fft_fused wins on this model
        (TINY_HW, {}),  # all three_stage
    ],
)
def test_planned_net_matches_direct(hw, kwargs):
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=3)
    plan = plan_net(spec, 16, 16, hw=hw, **kwargs)
    ex = NetExecutor(spec, ws, plan)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, 16, 4)) * 0.1, jnp.float32)
    y = ex(x)
    ref = run_direct(spec, ws, x)
    assert y.shape == ref.shape
    rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    assert rel < 1e-3, (plan.algos(), rel)


def test_executor_reuses_cache_across_buckets():
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=3)
    plan = plan_net(spec, 16, 16, hw=BIG_HW)
    ex = NetExecutor(spec, ws, plan)
    rng = np.random.default_rng(0)
    ex(jnp.asarray(rng.standard_normal((1, 16, 16, 4)), jnp.float32))
    first = ex.cache.stats()
    assert (first["hits"], first["misses"], first["entries"]) == (0, 4, 4)
    assert first["bytes"] == ex.cache.nbytes
    # second request, same bucket: pure hits, no recompile
    ex(jnp.asarray(rng.standard_normal((1, 16, 16, 4)), jnp.float32))
    assert ex.cache.stats()["hits"] == 4
    assert ex.compile_count == 1
    # new bucket: recompiles the program but the transforms still hit
    ex(jnp.asarray(rng.standard_normal((1, 32, 32, 4)), jnp.float32))
    assert ex.cache.stats()["hits"] == 8
    assert ex.cache.stats()["misses"] == 4
    assert ex.compile_count == 2


def test_executor_validates_weights_and_input():
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=0)
    plan = plan_net(spec, 16, 16, hw=BIG_HW)
    missing = dict(ws)
    missing.pop(spec.conv_layers()[0][0])
    with pytest.raises(ValueError):
        NetExecutor(spec, missing, plan)
    ex = NetExecutor(spec, ws, plan)
    with pytest.raises(ValueError):
        ex(jnp.zeros((16, 16, 4)))  # not NHWC


def test_executor_rejects_stale_or_incomplete_plan():
    import dataclasses

    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=0)
    plan = plan_net(spec, 16, 16, hw=BIG_HW)
    # plan missing a conv layer fails at init, not at request time
    truncated = dataclasses.replace(plan, layers=plan.layers[:-1])
    with pytest.raises(ValueError, match="plan missing conv layer"):
        NetExecutor(spec, ws, truncated)
    # plan whose geometry disagrees with the spec (stale plan file)
    bad_layer = dataclasses.replace(
        plan.layers[0],
        spec=dataclasses.replace(plan.layers[0].spec, c_out=32),
    )
    stale = dataclasses.replace(
        plan, layers=(bad_layer,) + plan.layers[1:]
    )
    with pytest.raises(ValueError, match="geometry"):
        NetExecutor(spec, ws, stale)
    # plan for a different net
    other = dataclasses.replace(plan, net="other-net")
    with pytest.raises(ValueError, match="plan is for net"):
        NetExecutor(spec, ws, other)


def test_executor_masked_ragged_batch_matches_per_image_runs():
    """Images smaller than the bucket must serve exactly: the extent mask
    stops conv outputs in the padded margin from bleeding back across the
    true-image edge (without it, a 48x48 image in a 64 bucket is ~0.24
    relative error at the edges)."""
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=7)
    plan = plan_net(spec, 64, 64, hw=BIG_HW)
    ex = NetExecutor(spec, ws, plan)
    rng = np.random.default_rng(4)
    small = jnp.asarray(rng.standard_normal((48, 48, 4)) * 0.1, jnp.float32)
    full = jnp.asarray(rng.standard_normal((64, 64, 4)) * 0.1, jnp.float32)
    batch = jnp.zeros((2, 64, 64, 4), jnp.float32)
    batch = batch.at[0, :48, :48].set(small).at[1].set(full)
    y = ex(batch, sizes=jnp.asarray([[48, 48], [64, 64]], jnp.int32))
    ref_small = run_direct(spec, ws, small[None])[0]
    ref_full = run_direct(spec, ws, full[None])[0]
    oh, ow, _ = ref_small.shape
    rel_small = float(
        jnp.abs(y[0, :oh, :ow] - ref_small).max() / jnp.abs(ref_small).max()
    )
    rel_full = float(jnp.abs(y[1] - ref_full).max() / jnp.abs(ref_full).max())
    assert rel_small < 1e-3, rel_small
    assert rel_full < 1e-3, rel_full


# ---------------------------------------------------------------- serving


def test_server_buckets_pads_and_crops():
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=5)
    plan = plan_net(spec, 16, 16, hw=BIG_HW)
    srv = ConvServer(
        NetExecutor(spec, ws, plan),
        ConvServeConfig(max_batch=4, buckets=(16, 32)),
    )
    rng = np.random.default_rng(1)
    imgs = {
        0: rng.standard_normal((16, 16, 4)).astype(np.float32),
        1: rng.standard_normal((32, 32, 4)).astype(np.float32),
        2: rng.standard_normal((16, 16, 4)).astype(np.float32),
        3: rng.standard_normal((24, 24, 4)).astype(np.float32),  # ragged:
        # rides zero-padded in the 32 bucket, exercising the extent mask
    }
    out = srv.run([ImageRequest(rid, im) for rid, im in imgs.items()])
    assert set(out) == {0, 1, 2, 3}
    assert out[0].shape == (4, 4, 16)  # 16 -> /2 -> /2 through two pools
    assert out[1].shape == (8, 8, 16)
    assert out[3].shape == (6, 6, 16)
    # each output equals the net run on that image alone
    for rid, im in imgs.items():
        ref = run_direct(spec, ws, jnp.asarray(im)[None])[0]
        rel = float(jnp.abs(out[rid] - ref).max() / jnp.abs(ref).max())
        assert rel < 1e-3, (rid, rel)


def test_server_second_request_hits_kernel_cache():
    """Acceptance criterion: repeated shapes reuse cached transforms."""
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=5)
    plan = plan_net(spec, 16, 16, hw=BIG_HW)
    srv = ConvServer(
        NetExecutor(spec, ws, plan),
        ConvServeConfig(max_batch=2, buckets=(16,)),
    )
    rng = np.random.default_rng(2)
    img = rng.standard_normal((16, 16, 4)).astype(np.float32)
    srv.run([ImageRequest(0, img)])
    first = srv.stats()
    assert first["cache"]["misses"] == 4 and first["cache"]["hits"] == 0
    assert first["waves"] == 1
    srv.run([ImageRequest(1, img)])
    second = srv.stats()
    assert second["cache"]["misses"] == 4  # nothing re-transformed
    assert second["cache"]["hits"] == 4
    assert second["waves"] == 2
    # same bucket, no recompile -- and the count is reported per bucket
    assert second["compiled_programs"] == 1
    assert second["compiles_per_bucket"] == {16: 1}


def test_server_bounded_compilation_across_traffic():
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=5)
    plan = plan_net(spec, 16, 16, hw=BIG_HW)
    ex = NetExecutor(spec, ws, plan)
    srv = ConvServer(ex, ConvServeConfig(max_batch=4, buckets=(16, 32)))
    rng = np.random.default_rng(3)
    reqs = []
    for rid in range(11):  # ragged sizes within two buckets
        side = [12, 16, 20, 28, 32][rid % 5]
        reqs.append(
            ImageRequest(
                rid, rng.standard_normal((side, side, 4)).astype(np.float32)
            )
        )
    out = srv.run(reqs)
    assert len(out) == 11
    # 2 buckets x at most 3 power-of-two wave sizes (1, 2, 4)
    assert ex.compile_count <= 6


def test_server_rejects_oversized_and_misaligned_buckets():
    spec = tiny_testnet(4)
    ws = init_weights(spec, seed=0)
    plan = plan_net(spec, 16, 16, hw=BIG_HW)
    ex = NetExecutor(spec, ws, plan)
    with pytest.raises(ValueError):
        ConvServer(ex, ConvServeConfig(buckets=(18,)))  # pool factor 4
    srv = ConvServer(ex, ConvServeConfig(buckets=(16,)))
    big = ImageRequest(0, np.zeros((64, 64, 4), np.float32))
    with pytest.raises(ValueError):
        srv.run([big])


# ------------------------------------------------- tune.py satellite fixes


def test_predict_r_within_bounds():
    from repro.core.tune import _CANDIDATES, predict_r

    for hw in (analysis.SKYLAKE_X, analysis.MOBILE_I7, BIG_HW):
        for c in (16, 64, 256, 1024):
            r = predict_r(c, c, hw=hw)
            assert r in _CANDIDATES
            r_max = analysis.max_r(hw, c, c, 7)
            # never above the private-memory bound unless nothing fits
            assert r <= r_max or r == min(_CANDIDATES)


def test_feasible_candidates_respects_r_max():
    """Seed bug: candidates above r_max were admitted whenever
    r_max < min(candidates)."""
    from repro.core.tune import feasible_candidates

    feas = feasible_candidates(
        1024, 1024, hw=analysis.MOBILE_I7, candidates=(4, 8, 16)
    )
    assert feas == [4]  # r_max ~ 0: only the floor survives
    feas = feasible_candidates(
        16, 16, hw=analysis.SKYLAKE_X, candidates=(4, 8, 16, 1024)
    )
    assert 1024 not in feas


def test_wisdom_write_is_atomic(tmp_path, monkeypatch):
    from repro.core import tune

    calls = {"n": 0}
    monkeypatch.setattr(tune, "measure_r", lambda *a, **k: 16)
    path = tmp_path / "wisdom.json"
    r = tune.tuned_r(8, 8, 4, 4, wisdom_path=path)
    assert r == 16
    assert json.loads(path.read_text())  # valid JSON, no .tmp leftovers
    assert list(tmp_path.iterdir()) == [path]
    # cached: no re-measure
    monkeypatch.setattr(
        tune, "measure_r", lambda *a, **k: calls.__setitem__("n", 1)
    )
    assert tune.tuned_r(8, 8, 4, 4, wisdom_path=path) == 16
    assert calls["n"] == 0
