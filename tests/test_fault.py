"""Fault tolerance: injected failures -> restore-and-continue; stragglers."""

import jax
import jax.numpy as jnp
import numpy as np

import pytest

from repro.checkpoint import io as ckpt_io
from repro.convserve.runtime import SimClock
from repro.runtime.fault import (
    FAULT_CACHE_CORRUPT,
    FAULT_CRASH,
    FAULT_SLOW,
    FailureInjector,
    FaultPlan,
    InjectedFailure,
    ReplicaFault,
    StragglerWatchdog,
    run_supervised,
)
from repro.train.loop import LoopConfig, train_loop


def _toy_step():
    @jax.jit
    def step(state, batch):
        w = state["params"]["w"]
        x, y = batch["x"], batch["y"]
        pred = x @ w
        loss = jnp.mean((pred - y) ** 2)
        g = jax.grad(lambda ww: jnp.mean((x @ ww - y) ** 2))(w)
        new = {
            "params": {"w": w - 0.1 * g},
            "step": state["step"] + 1,
        }
        return new, {"loss": loss, "grad_norm": jnp.linalg.norm(g)}

    return step


def _batches(seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.standard_normal((4, 1))

    def next_batch(step):
        r = np.random.default_rng(step)
        x = r.standard_normal((16, 4)).astype(np.float32)
        return {
            "x": jnp.asarray(x),
            "y": jnp.asarray((x @ w_true).astype(np.float32)),
        }

    return next_batch


def test_loop_recovers_from_injected_failures(tmp_path, capsys):
    state = {"params": {"w": jnp.zeros((4, 1))}, "step": jnp.int32(0)}
    injector = FailureInjector(fail_at_steps=(7, 13))
    final = train_loop(
        state=state,
        train_step=_toy_step(),
        next_batch=_batches(),
        cfg=LoopConfig(
            total_steps=20, ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100
        ),
        injector=injector,
    )
    # both failures fired and the loop still completed all 20 steps
    assert injector.fired == {7, 13}
    assert int(final["step"]) >= 18  # restored to ckpt step then re-ran
    out = capsys.readouterr().out
    assert out.count("[fault]") == 2


def test_loop_resumes_from_disk(tmp_path):
    state0 = {"params": {"w": jnp.zeros((4, 1))}, "step": jnp.int32(0)}
    train_loop(
        state=state0, train_step=_toy_step(), next_batch=_batches(),
        cfg=LoopConfig(total_steps=10, ckpt_dir=str(tmp_path), ckpt_every=4,
                       log_every=100),
    )
    assert ckpt_io.latest_step(tmp_path) == 9
    # a NEW process picks up from the checkpoint
    final = train_loop(
        state=state0, train_step=_toy_step(), next_batch=_batches(),
        cfg=LoopConfig(total_steps=12, ckpt_dir=str(tmp_path), ckpt_every=4,
                       log_every=100),
    )
    assert int(final["step"]) == 12


def test_straggler_watchdog():
    wd = StragglerWatchdog(factor=3.0, min_steps=5)
    for i in range(10):
        assert wd.observe(i, 0.1) is None
    alarm = wd.observe(10, 1.0)
    assert alarm is not None and alarm["p50"] < 0.2
    assert len(wd.alarms) == 1


def test_fault_plan_routes_through_injected_clock():
    clock = SimClock()
    plan = FaultPlan([
        ReplicaFault(t=2.0, kind=FAULT_SLOW, replica=1, factor=8.0),
        ReplicaFault(t=1.0, kind=FAULT_CRASH, replica=0),
        ReplicaFault(t=3.0, kind=FAULT_CACHE_CORRUPT),
    ], clock=clock)
    # schedule is sorted by time regardless of construction order
    assert plan.next_t() == 1.0 and plan.pending() == 3
    assert plan.due() == []  # clock still at 0
    clock.advance(2.5)
    ripe = plan.due()  # no explicit `now`: reads the injected clock
    assert [f.kind for f in ripe] == [FAULT_CRASH, FAULT_SLOW]
    assert plan.due() == []  # exactly once
    assert plan.next_t() == 3.0
    clock.advance(10.0)
    assert [f.kind for f in plan.due()] == [FAULT_CACHE_CORRUPT]
    assert plan.next_t() == float("inf") and plan.pending() == 0
    s = plan.stats()
    assert s["pending"] == 0 and len(s["fired"]) == 3
    assert [f["t"] for f in s["fired"]] == [1.0, 2.0, 3.0]


def test_fault_plan_without_clock_requires_explicit_now():
    plan = FaultPlan([ReplicaFault(t=1.0, kind=FAULT_CRASH, replica=0)])
    with pytest.raises(ValueError, match="no injected clock"):
        plan.due()
    assert len(plan.due(now=1.0)) == 1


def test_replica_fault_validates():
    with pytest.raises(ValueError, match="unknown fault kind"):
        ReplicaFault(t=0.0, kind="meteor")
    with pytest.raises(ValueError, match="needs a target replica"):
        ReplicaFault(t=0.0, kind=FAULT_CRASH)
    with pytest.raises(ValueError, match="needs a target replica"):
        ReplicaFault(t=0.0, kind=FAULT_SLOW)
    # cache corruption targets the shared cache: no replica needed
    ReplicaFault(t=0.0, kind=FAULT_CACHE_CORRUPT)


def test_straggler_watchdog_stamps_alarms_with_injected_clock():
    clock = SimClock()
    wd = StragglerWatchdog(factor=3.0, min_steps=5, clock=clock)
    for i in range(6):
        wd.observe(i, 0.1)
    clock.advance(42.0)
    alarm = wd.observe(6, 1.0)
    assert alarm is not None and alarm["t"] == 42.0


def test_supervisor_restarts():
    calls = {"n": 0}

    def work(step):
        calls["n"] += 1
        if calls["n"] == 2:
            raise InjectedFailure("boom")
        return step + 5

    def restore():
        return 0

    final = run_supervised(
        work, start_step=0, total_steps=10, restore=restore
    )
    assert final >= 10
