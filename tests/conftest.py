import os
import sys

# tests must see exactly ONE device (the dry-run sets its own XLA_FLAGS)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.models.runtime_flags import FLAGS

# tests validate numerics against f32 oracles; the perf configuration's
# bf16 P-matrix (runtime_flags.set_optimized) is exercised explicitly in
# test_flash_attention.py with appropriate tolerances
FLAGS.flash_p_dtype = "float32"


@pytest.fixture
def rng():
    return np.random.default_rng(0)
