"""Sharding rules + compressed collectives, on a multi-device subprocess
(the main pytest process is pinned to 1 CPU device)."""

import json
import os
import subprocess
import sys
import textwrap


_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    # pin the child to CPU: auto-detection on TPU-toolchain images hangs
    # retrying the metadata service; the forced host-platform count still
    # provides the 8 fake devices these tests need
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharding_rules_engine():
    out = _run("""
        import jax, json
        import jax.numpy as jnp
        from repro.distributed.sharding import param_spec, cache_spec, batch_spec
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        specs = {}
        # TP on projection outputs; FSDP on the other big dim
        specs["wq"] = str(param_spec("stack/0/layers/0/attn/wq", (4, 1024, 512), mesh))
        # kv heads 2 < model 4 -> replicate the head dim (divisibility fallback)
        specs["wk_small"] = str(param_spec("a/wk", (10, 6), mesh))
        # MoE expert tables get EP on the expert dim
        specs["moe_w1"] = str(param_spec("stack/0/layers/0/moe/w1", (8, 64, 32), mesh))
        # norms replicated
        specs["norm"] = str(param_spec("stack/0/layers/0/ln1", (4, 1024), mesh))
        # kv cache: batch->data, heads->model
        specs["kv"] = str(cache_spec("groups/0/0/self/k", (4, 8, 128, 4, 64), mesh))
        # kv cache with 1 head: context parallel over seq
        specs["kv_cp"] = str(cache_spec("groups/0/0/self/k", (4, 8, 128, 1, 64), mesh))
        # batch not divisible -> replicated
        specs["batch_odd"] = str(batch_spec("tokens", (3, 128), mesh))
        specs["batch"] = str(batch_spec("tokens", (8, 128), mesh))
        print(json.dumps(specs))
    """)
    specs = json.loads(out.strip().splitlines()[-1])
    assert "model" in specs["wq"] and "data" in specs["wq"]
    assert "model" not in specs["wk_small"]
    assert specs["moe_w1"].startswith("PartitionSpec('model'")
    assert "model" not in specs["norm"] and "data" not in specs["norm"]
    assert "'data'" in specs["kv"] and "'model'" in specs["kv"]
    kv_cp = specs["kv_cp"]
    assert kv_cp.index("model") > kv_cp.index("data")  # seq dim, not head dim
    assert specs["batch_odd"] == "PartitionSpec(None, None)" or "data" not in specs["batch_odd"]
    assert "'data'" in specs["batch"]


def test_compressed_allreduce_subprocess():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.distributed.collectives import make_compressed_allreduce, init_residuals
        mesh = jax.make_mesh((8,), ("data",))
        ar = make_compressed_allreduce(mesh, "data")
        rng = np.random.default_rng(0)
        g_global = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
        # each shard holds one row; allreduce(mean) should give the row-mean
        g = jax.device_put(g_global, NamedSharding(mesh, P("data", None)))
        r = jax.device_put(jnp.zeros((8, 64)), NamedSharding(mesh, P("data", None)))
        gs, rs = ar({"g": g}, {"g": r})
        got = np.asarray(gs["g"])[0]
        want = np.asarray(g_global).mean(0)
        err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        print("ERR", err)
        assert err < 0.05, err
    """)
    assert "ERR" in out


def test_mesh_construction_subprocess():
    out = _run("""
        import os
        # make_production_mesh needs 512 devices; host mesh uses available
        import jax
        from repro.launch.mesh import make_host_mesh
        m = make_host_mesh(model=2)
        print(dict(m.shape))  # plain dict: stable repr across jax versions
    """)
    assert "'data': 4" in out.replace('"', "'") and "'model': 2" in out.replace('"', "'")
