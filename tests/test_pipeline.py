"""The unified tile engine: one parity matrix over every registered
transformed algorithm x every engine scenario, plus the Transform
protocol itself and FFT-backed fusion groups through the staged engine.

Exactness oracle is always `lax.conv_general_dilated` (the direct conv),
to fp32 transform tolerance.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.convnets import fft_fewchannel
from repro.convserve import (
    Engine,
    NetExecutor,
    init_weights,
    run_direct,
)
from repro.convserve.graph import NetSpec, conv
from repro.convserve.plan import LayerPlan, NetPlan
from repro.convserve.planner import plan_net
from repro.core import analysis, registry, transforms, tune
from repro.core.registry import ConvSpec

BIG_HW = analysis.HardwareModel(
    name="big", peak_flops=1e12, dram_bw=1e11, fast_shared_bw=5e11,
    fast_shared_bytes=1 << 30, private_bytes=1 << 24,
)

# every registered algorithm that realizes a transform tiling (the
# Pallas kernel included: it inherits the Winograd family's algebra)
TRANSFORMED = tuple(
    n for n in registry.names() if registry.get(n).tile_algebra(
        registry.AlgoPlan(
            n, ConvSpec(h=16, w=16, c_in=4, c_out=4, k=3, pad=1),
            {"m": 4, "t_fft": 8},
        )
    ) is not None
)


def _lax_ref(x, w, pad=0, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def _rel(y, ref):
    return float(
        jnp.abs(y.astype(jnp.float32) - ref.astype(jnp.float32)).max()
        / (jnp.abs(ref.astype(jnp.float32)).max() + 1e-9)
    )


def _forced_plan(algo, spec):
    """An AlgoPlan for `algo` on `spec` with small deterministic params."""
    return registry.plan_conv(
        spec, BIG_HW, algo=algo, hints={"m": 4, "t_fft": 8, "r_tiles": 6}
    )


# ---------------------------------------------------- the parity matrix


SCENARIOS = ("plain", "stride2", "grouped", "ragged", "bias_relu")


@pytest.mark.parametrize("scenario", SCENARIOS)
@pytest.mark.parametrize("algo", TRANSFORMED)
def test_engine_parity_matrix(algo, scenario):
    """Every registered transformed algorithm x every engine scenario
    agrees exactly (fp32 transform tolerance) with the direct conv."""
    rng = np.random.default_rng(17)
    stride = 2 if scenario == "stride2" else 1
    groups = 4 if scenario == "grouped" else 1
    b, h, w, c_in, c_out = 2, 18, 15, 8, 8
    x = jnp.asarray(rng.standard_normal((b, h, w, c_in)) * 0.1, jnp.float32)
    wk = jnp.asarray(
        rng.standard_normal((3, 3, c_in // groups, c_out)) * 0.1, jnp.float32
    )
    spec = ConvSpec.from_tensors(x, wk, pad=1, stride=stride, groups=groups)
    assert registry.get(algo).supports(spec), (algo, scenario)
    ap = _forced_plan(algo, spec)
    alg = registry.get(ap.algo)

    if scenario == "ragged":
        # zero-padded batch with per-sample true extents, served through
        # the executor's extent masking: each sample must equal running
        # it alone unpadded
        net = NetSpec("one", (conv(c_in, c_out, k=3, pad=1),))
        plan = NetPlan(
            net="one", hw=BIG_HW.name, dtype="float32", input_hw=(h, w),
            layers=(LayerPlan.from_algo_plan(0, ap),),
        )
        ws = {0: wk}
        ex = NetExecutor(net, ws, plan)
        sizes = jnp.asarray([[h, w], [12, 9]], jnp.int32)
        xr = x.at[1, 12:, :, :].set(0.0).at[1, :, 9:, :].set(0.0)
        y = ex(xr, sizes)
        full = _lax_ref(xr[:1], wk, pad=1)
        assert _rel(y[0], full[0]) < 5e-5, algo
        small = _lax_ref(xr[1:, :12, :9], wk, pad=1)
        oh, ow = 12, 9
        assert _rel(y[1, :oh, :ow], small[0]) < 5e-5, algo
        # masked region stays zero
        assert float(jnp.abs(y[1, oh:]).max()) == 0.0
        assert float(jnp.abs(y[1, :, ow:]).max()) == 0.0
        return

    ref = _lax_ref(x, wk, pad=1, stride=stride, groups=groups)
    if scenario == "bias_relu":
        bvec = jnp.asarray(rng.standard_normal(c_out) * 0.1, jnp.float32)
        runner = alg.fuse_epilogue(
            ap, lambda y: jax.nn.relu(y + bvec)
        )
        y = runner(x, wk, alg.prepare_weights(wk, ap))
        ref = jax.nn.relu(ref + bvec)
    else:
        y = alg.execute(x, wk, alg.prepare_weights(wk, ap), ap)
    assert y.shape == ref.shape, (algo, scenario)
    assert _rel(y, ref) < 5e-5, (algo, scenario)


# ------------------------------------------------- the Transform protocol


@pytest.mark.parametrize(
    "tr",
    [
        transforms.WinogradTransform(m=4, k=3),
        transforms.WinogradTransform(m=2, k=5),
        transforms.FFTTransform(t=8, k=3),
        transforms.FFTTransform(t=16, k=5),
    ],
)
def test_transform_roundtrip_is_correlation(tr):
    """forward -> multiply -> inverse on a single tile equals the valid
    cross-correlation of that tile, for both families."""
    rng = np.random.default_rng(3)
    c_in, c_out = 3, 5
    tiles = jnp.asarray(
        rng.standard_normal((2, tr.t, tr.t, c_in)), jnp.float32
    )
    wk = jnp.asarray(
        rng.standard_normal((tr.k, tr.k, c_in, c_out)), jnp.float32
    )
    wt = tr.kernel_transform(wk)
    y = tr.inverse(tr.multiply(tr.forward(tiles), wt))
    ref = _lax_ref(tiles, wk)  # valid correlation: (2, T', T', C')
    assert y.shape == (2, tr.t_out, tr.t_out, c_out)
    assert _rel(y, ref) < 1e-4, tr


def test_tile_algebra_terms():
    wino = transforms.WinogradTransform(m=5, k=3).algebra
    assert (wino.t, wino.t_out, wino.alpha) == (7, 5, 1)
    assert wino.domain_points == 49 and wino.elem_bytes == 4
    assert wino.kernel_matrix_bytes(8, 16) == 4 * 49 * 8 * 16
    assert wino.kernel_matrix_bytes(8, 16, groups=4) == 4 * 49 * 2 * 16
    fft = transforms.FFTTransform(t=16, k=3).algebra
    assert (fft.t, fft.t_out, fft.alpha) == (16, 14, 2)
    # rfft half-spectrum, complex elements
    assert fft.domain_points == 16 * 9 and fft.elem_bytes == 8
    assert fft.kernel_matrix_bytes(4, 4) == 8 * 16 * 9 * 4 * 4
    # the complex working set halves the feasible R vs a same-T real domain
    r_fft = analysis.max_r_ta(BIG_HW, 8, 8, fft)
    r_real = analysis.max_r_ta(
        BIG_HW, 8, 8, dataclasses.replace(fft, elem_bytes=4)
    )
    assert r_fft <= r_real // 2 + 1


def test_fft_domain_dtypes():
    tr = transforms.FFTTransform(t=8, k=3)
    assert tr.domain_dtype(jnp.float32) == jnp.complex64
    assert tr.domain_dtype(jnp.bfloat16) == jnp.complex64
    assert tr.domain_dtype(jnp.float64) == jnp.complex128
    u = tr.forward(jnp.zeros((1, 8, 8, 2), jnp.bfloat16))
    assert u.dtype == jnp.complex64  # bf16 lifted to the fp32 domain


def test_fft_bf16_real_path():
    """bf16 FFT: computed in fp32, cast back -- a real path, not a
    fallback, and bf16-accurate against the f32 oracle."""
    rng = np.random.default_rng(5)
    x32 = jnp.asarray(rng.standard_normal((1, 16, 16, 8)), jnp.float32)
    w32 = jnp.asarray(rng.standard_normal((3, 3, 8, 8)), jnp.float32)
    x, wk = x32.astype(jnp.bfloat16), w32.astype(jnp.bfloat16)
    spec = ConvSpec.from_tensors(x, wk, pad=1)
    assert registry.get("fft_fused").supports(spec)
    ap = _forced_plan("fft_fused", spec)
    y = registry.get("fft_fused").execute(x, wk, None, ap)
    assert y.dtype == jnp.bfloat16
    ref = _lax_ref(x32, w32, pad=1)
    assert _rel(y, ref) < 0.1  # bf16 has ~3 decimal digits


# -------------------------------------------- wisdom keyed by family


def test_wisdom_keys_never_collide_across_families(tmp_path, monkeypatch):
    """A Winograd-R tune and an FFT-T tune for the same layer live under
    distinct wisdom keys -- neither lookup sees the other's entry."""
    wino = transforms.WinogradTransform(m=5, k=3)
    fft = transforms.FFTTransform(t=16, k=3)
    kw = tune._key(wino, 32, 32, 8, 8)
    kf = tune._key(fft, 32, 32, 8, 8)
    assert kw != kf and "winograd" in kw and "fft" in kf
    path = tmp_path / "wisdom.json"
    monkeypatch.setattr(
        tune, "measure_r", lambda *a, **k: 24 if k["transform"].family == "winograd" else 12
    )
    assert tune.tuned_r(32, 32, 8, 8, transform=wino, wisdom_path=path) == 24
    assert tune.tuned_r(32, 32, 8, 8, transform=fft, wisdom_path=path) == 12
    # both entries coexist on disk; lookups are family-scoped
    stored = json.loads(path.read_text())
    assert len(stored) == 2
    assert tune.lookup_r(32, 32, 8, 8, transform=wino, wisdom_path=path) == 24
    assert tune.lookup_r(32, 32, 8, 8, transform=fft, wisdom_path=path) == 12


# ------------------------------------- FFT-backed cross-layer fusion


def test_fft_net_plans_fft_with_fusion_group():
    """The few-channel net picks the FFT transform per layer (the cost
    model's DRAM-bound tile-amortization argument) and folds the chain
    into one FFT fusion group."""
    spec = fft_fewchannel(4)
    plan = plan_net(spec, 48, 48, hw=analysis.SKYLAKE_X)
    assert all(a == "fft_fused" for a in plan.algos()), plan.algos()
    assert len(plan.groups) == 1 and len(plan.groups[0].layers) == 3


@pytest.mark.parametrize("tile_rows", [0, 5, 16])
def test_fft_fusion_group_exact_any_tiling(tile_rows):
    """FFT-backed fusion groups through the generic staged engine:
    fused == unfused == direct at every super-tile row count, with
    bias+relu epilogues, ragged batches and multi-tile seams."""
    spec = fft_fewchannel(4)
    ws = init_weights(spec, seed=1)
    plan = plan_net(spec, 24, 24, hw=analysis.SKYLAKE_X)
    assert plan.groups, "planner built no FFT fusion group"
    plan = dataclasses.replace(
        plan,
        groups=(dataclasses.replace(plan.groups[0], tile_rows=tile_rows),),
    )
    fused = NetExecutor(spec, ws, plan)
    unfused = NetExecutor(spec, ws, dataclasses.replace(plan, groups=()))
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((2, 24, 24, 4)) * 0.1, jnp.float32)
    ref = run_direct(spec, ws, x)
    assert _rel(fused(x), ref) < 5e-5, tile_rows
    assert _rel(fused(x), unfused(x)) < 1e-6, tile_rows
    # ragged: the padded second sample equals its unpadded solo run
    sizes = jnp.asarray([[24, 24], [17, 13]], jnp.int32)
    xr = x.at[1, 17:].set(0.0).at[1, :, 13:].set(0.0)
    y = fused(xr, sizes)
    solo = run_direct(spec, ws, xr[1:, :17, :13])
    assert _rel(y[1, :17, :13], solo[0]) < 5e-5, tile_rows


def test_mixed_family_chain_rejected():
    """Winograd and FFT tiles cannot share a fusion group: the planner's
    chainability gate keeps families homogeneous."""
    s = ConvSpec(h=16, w=16, c_in=8, c_out=8, k=3, pad=1)
    p = lambda algo: registry.AlgoPlan(algo, s, {})  # noqa: E731
    assert not registry.get("fft_fused").can_chain(
        p("fft_fused"), p("l3_fused")
    )
    assert registry.get("fft_fused").can_chain(
        p("fft_fused"), p("fft_fused")
    )


def test_fft_fusion_group_via_engine_compile():
    """End to end through the public Engine: compile the FFT net, serve
    it, and hit the kernel cache with complex right-hand matrices."""
    spec = fft_fewchannel(4)
    ws = init_weights(spec, seed=0)
    engine = Engine(hw=analysis.SKYLAKE_X)
    net = engine.compile(spec, ws, input_hw=(32, 32))
    assert net.program.n_fused == 1
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 32, 32, 4)) * 0.1, jnp.float32)
    y = net(x)
    assert _rel(y, run_direct(spec, ws, x)) < 5e-5
    stats = net.stats()
    assert stats["cache"]["entries"] == 3  # one complex wt per conv
    v = next(iter(net.cache._store.values()))
    assert jnp.iscomplexobj(v)


def test_plan_roundtrip_preserves_fft_groups(tmp_path):
    spec = fft_fewchannel(4)
    plan = plan_net(spec, 32, 32, hw=analysis.SKYLAKE_X)
    path = tmp_path / "fft.plan.json"
    plan.save(path)
    again = NetPlan.load(path)
    assert again == plan and again.groups == plan.groups


# --------------------------------------- engine working-set accounting


def test_shared_buffer_plan_family_exact():
    from repro.core.pipeline import shared_buffer_plan

    fft = transforms.FFTTransform(t=16, k=3)
    sb = shared_buffer_plan(fft, r=8, c_in=4, c_out=6)
    sb.validate()
    assert sb.elem_bytes == 8 and sb.t2 == 16 * 9
    assert sb.bytes == 8 * (16 * 9 + 1) * 8 * 6
    wino = transforms.WinogradTransform(m=5, k=3)
    sb2 = shared_buffer_plan(wino, r=8, c_in=4, c_out=6)
    assert sb2.elem_bytes == 4 and sb2.t2 == 49


def test_epilogue_in_task_loop_matches_post_pass():
    """fuse_epilogue folds glue into the scan; it must equal applying the
    same glue to the assembled output (tiles abut), per family."""
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((1, 20, 20, 6)) * 0.1, jnp.float32)
    wk = jnp.asarray(rng.standard_normal((3, 3, 6, 6)) * 0.1, jnp.float32)
    bvec = jnp.asarray(rng.standard_normal(6) * 0.1, jnp.float32)
    glue = lambda y: jax.nn.relu(y + bvec)  # noqa: E731
    spec = ConvSpec.from_tensors(x, wk, pad=1)
    for algo in ("l3_fused", "fft_fused"):
        ap = _forced_plan(algo, spec)
        alg = registry.get(algo)
        wt = alg.prepare_weights(wk, ap)
        y_in = alg.fuse_epilogue(ap, glue)(x, wk, wt)
        y_post = glue(alg.execute(x, wk, wt, ap))
        assert _rel(y_in, y_post) < 1e-6, algo
