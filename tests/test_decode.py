"""Prefill + incremental decode agree with the full forward for all archs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, list_archs
from repro.models import init_lm, lm_decode_step, lm_logits, lm_prefill


@pytest.mark.parametrize("name", list(list_archs()))
def test_prefill_decode_matches_forward(name, rng):
    cfg = get_arch(name).reduced()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    b, s, extra, max_len = 2, 24, 4, 40
    toks = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s + extra)), jnp.int32
    )
    kw = {}
    if cfg.is_encoder_decoder:
        kw["src_embeds"] = jnp.asarray(
            rng.standard_normal((b, 16, cfg.d_model)), jnp.float32
        )
    full = lm_logits(p, cfg, toks, **kw)
    logits_p, state = lm_prefill(p, cfg, toks[:, :s], max_len, **kw)
    scale = float(jnp.abs(full).max())
    assert float(jnp.abs(logits_p - full[:, s - 1]).max()) / scale < 2e-3
    for t in range(s, s + extra):
        lg, state = lm_decode_step(p, cfg, toks[:, t], jnp.int32(t), state)
        assert float(jnp.abs(lg - full[:, t]).max()) / scale < 2e-3, (name, t)


def test_sliding_window_ring_buffer_wraps(rng):
    """gemma3's reduced config has window 16 < prefix 24: the ring must wrap
    and still agree with the full forward (exercised above), and the cache
    must physically be window-sized."""
    cfg = get_arch("gemma3-1b").reduced()
    assert cfg.sliding_window == 16
    p = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 24)), jnp.int32)
    _, state = lm_prefill(p, cfg, toks, 64)
    # first group: superblocks of (5 local + 1 global)
    caches = state["groups"][0]
    local_cache = caches[0]["self"]
    glob_cache = caches[5]["self"]
    assert local_cache["k"].shape[2] == 16  # (n_repeat, B, window, H, hd)
    assert glob_cache["k"].shape[2] == 64  # dense max_len


def test_mamba_state_is_constant_size(rng):
    cfg = get_arch("mamba2-1.3b").reduced()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 32)), jnp.int32)
    _, st_small = lm_prefill(p, cfg, toks, 64)
    _, st_large = lm_prefill(p, cfg, toks, 4096)
    sz = lambda s: sum(x.size for x in jax.tree.leaves(s))
    assert sz(st_small) == sz(st_large)  # O(1) in context length
