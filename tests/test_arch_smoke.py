"""Per-architecture smoke tests on reduced configs (deliverable f).

Each assigned architecture instantiates a small same-family config and runs
one forward + one train-style loss/grad step on CPU, asserting output shapes
and the absence of NaNs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import init_lm, lm_logits, lm_loss

ARCHS = list(list_archs())


def _batch(cfg, rng, b=2, s=32):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.is_encoder_decoder:
        batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((b, 16, cfg.d_model)), jnp.float32
        )
    return batch


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10, ARCHS


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_no_nan(name, rng):
    cfg = get_arch(name).reduced()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)
    kw = (
        {"src_embeds": batch["src_embeds"]} if cfg.is_encoder_decoder else {}
    )
    logits = lm_logits(p, cfg, batch["tokens"], **kw)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_no_nan(name, rng):
    cfg = get_arch(name).reduced()
    p = init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)

    def loss_fn(pp):
        return lm_loss(pp, cfg, batch, remat=True)[0]

    loss, grads = jax.value_and_grad(loss_fn)(p)
    assert bool(jnp.isfinite(loss)), name
    # a reasonable init loss: close to uniform over the vocab
    assert float(loss) < np.log(cfg.vocab_size) * 3
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)


@pytest.mark.parametrize("name", ARCHS)
def test_full_config_matches_assignment(name):
    """The full (non-reduced) configs carry the exact assigned dimensions."""
    cfg = get_arch(name)
    sheet = {
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536),
        "mamba2-1.3b": (48, 2048, 1, 1, 0, 50280),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "deepseek-v3-671b": (61, 7168, 128, 128, 2048, 129280),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    }[name]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == sheet, (name, got, sheet)
    if name == "deepseek-v3-671b":
        assert cfg.moe.n_experts == 256 and cfg.moe.top_k == 8
        assert cfg.moe.n_shared == 1 and cfg.mla is not None and cfg.mtp
    if name == "moonshot-v1-16b-a3b":
        assert cfg.moe.n_experts == 64 and cfg.moe.top_k == 6
    if name == "mamba2-1.3b":
        assert cfg.ssm.d_state == 128
    if name == "zamba2-7b":
        assert cfg.ssm.d_state == 64 and cfg.shared_attn_period == 6
    if name == "gemma3-1b":
        assert cfg.local_global_period == 6 and cfg.n_kv_heads == 1
    if name == "seamless-m4t-medium":
        assert cfg.is_encoder_decoder
