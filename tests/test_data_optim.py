"""Data pipeline determinism + optimizer correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, Prefetcher, TokenStream
from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    global_norm,
    warmup_cosine,
)


def test_stream_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8)
    s1, s2 = TokenStream(cfg), TokenStream(cfg)
    b1, b2 = s1.batch_at(17), s2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shifted-by-one language modelling structure
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_stream_host_sharding_disjoint():
    a = TokenStream(DataConfig(1000, 32, 8, host_id=0, num_hosts=2))
    b = TokenStream(DataConfig(1000, 32, 8, host_id=1, num_hosts=2))
    assert a.local_batch == 4
    ba, bb = a.batch_at(3), b.batch_at(3)
    assert not np.array_equal(ba["tokens"], bb["tokens"])


def test_prefetcher_orders_batches():
    s = TokenStream(DataConfig(1000, 16, 4))
    pf = Prefetcher(s, start_step=5)
    try:
        for want in (5, 6, 7):
            step, batch = pf.next()
            assert step == want
            np.testing.assert_array_equal(
                batch["tokens"], s.batch_at(want)["tokens"]
            )
    finally:
        pf.close()


@pytest.mark.parametrize("moment_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges_quadratic(moment_dtype):
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, moment_dtype=moment_dtype)
    target = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)))
    params = {"w": jnp.zeros((8, 8))}
    opt = adamw_init(params, cfg)

    @jax.jit
    def step(params, opt):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw_update(params, g, opt, cfg)

    for _ in range(150):
        params, opt, metrics = step(params, opt)
    err = float(jnp.abs(params["w"] - target).max())
    assert err < 0.05, (moment_dtype, err)


def test_int8_moments_track_float32():
    cfg8 = AdamWConfig(lr=0.05, weight_decay=0.0, moment_dtype="int8")
    cfg32 = AdamWConfig(lr=0.05, weight_decay=0.0, moment_dtype="float32")
    rng = np.random.default_rng(1)
    target = jnp.asarray(rng.standard_normal((300,)))
    p8 = {"w": jnp.zeros((300,))}
    p32 = {"w": jnp.zeros((300,))}
    o8, o32 = adamw_init(p8, cfg8), adamw_init(p32, cfg32)
    for _ in range(60):
        g8 = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(p8)
        g32 = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(p32)
        p8, o8, _ = adamw_update(p8, g8, o8, cfg8)
        p32, o32, _ = adamw_update(p32, g32, o32, cfg32)
    # int8 moments land in the same neighbourhood as f32 moments
    d = float(jnp.abs(p8["w"] - p32["w"]).max())
    assert d < 0.15, d


def test_grad_clip_and_schedule():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0)
    params = {"w": jnp.zeros((4,))}
    opt = adamw_init(params, cfg)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw_update(params, g, opt, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    assert float(m["clip"]) == pytest.approx(1.0 / 200.0)
    assert float(warmup_cosine(jnp.int32(0), warmup=10, total=100)) == 0.0
    peak = float(warmup_cosine(jnp.int32(10), warmup=10, total=100))
    end = float(warmup_cosine(jnp.int32(100), warmup=10, total=100))
    assert peak == pytest.approx(1.0)
    assert 0.0 < end < 0.15
    assert float(global_norm({"a": jnp.ones((4,))})) == pytest.approx(2.0)
